//! The sharded execution engine: a persistent pool of shard workers over
//! per-shard work deques, with optional work stealing and a background
//! rebalancer that re-replicates hot whole tables at runtime.
//!
//! Execution of one batch:
//!
//! 1. **Split** — every request's per-table id list becomes one whole
//!    *sub-request* (`(slot, table, ids)`), homed to the shard owning the
//!    plurality of its rows (whole tables: a replica, round-robin).
//!    Sub-requests are never split into per-shard partial sums — f32
//!    addition is not associative, so no partial-sum merge order could
//!    reproduce the unsharded kernel bit for bit.
//! 2. **Enqueue** — sub-requests land on their home shard's deque (one
//!    lock per shard per batch).
//! 3. **Pool** — each worker drains its own deque front-to-back; when
//!    [`ShardConfig::steal`] is set, an idle worker pulls whole
//!    sub-requests from the busiest peer's deque instead of sleeping.
//!    A segment whose ids span row chunks runs the chunked kernels in
//!    [`crate::shard::exec`] — id-order-fixed arithmetic over the owning
//!    chunk slices — so the result is bit-identical to the unsharded
//!    kernel no matter which worker executes it.
//! 4. **Gather** — each segment is computed exactly once, so the leader
//!    just places results at their `(slot, table)` offsets; output is
//!    deterministic regardless of completion order, by construction.
//!
//! **Runtime re-replication:** routing and slices live in an immutable
//! [`Placement`] snapshot behind an `RwLock<Arc<_>>`. Each batch clones
//! the `Arc` once; the rebalancer builds a new placement (duplicating /
//! dropping whole-table replicas ranked by exponential-decay load
//! windows — [`DecayWindow`] — fed by the traffic since its last tick,
//! so bursty tables keep their heat across one-window gaps) and swaps
//! it atomically between batches. In-flight batches keep serving from
//! their snapshot.
//!
//! **Per-shard wakeups:** every worker parks on its own condvar
//! ([`WorkerGate`]); the leader notifies exactly the shards whose deques
//! received work (all of them when stealing is on, since any idle peer
//! may steal). Producers update the queued counters *before* taking the
//! gate lock a waiter holds from its counter check until it parks, so a
//! wakeup can never be lost — which is why the old scheme's 20 ms idle
//! polling tick is gone entirely.
//!
//! **Tiered storage:** with [`ShardConfig::resident_budget`] set, every
//! placement entry is a [`SliceCell`] whose tier is resident or spilled
//! ([`crate::shard::store`]). Execution resolves exactly the cells a
//! segment touches, promoting spilled ones from disk on demand under a
//! bounded resident-bytes budget; the coldest cells (same decay heat as
//! the rebalancer) are demoted to disk in their native quantized
//! encoding. The disk work runs on the store's async spill I/O engine:
//! demotions stream to `*.tmp` + rename on a background pool with the
//! registry lock held only for cell-state flips, a segment touching
//! several spilled chunks prefetches them with overlapping reads, and
//! startup sweeps the spill directory for orphans of unclean shutdowns
//! (re-adopting byte-identical files). Reloaded bytes are identical to
//! the spilled bytes, so tier transitions never move a bit of output.
//!
//! **Fault containment:** worker panics are caught per task (the segment
//! is returned zeroed and counted in [`ShardStats::panics`]) and every
//! shared lock is poison-tolerant, so one crashing task can neither
//! wedge a batch nor cascade a panic through `serve_trace` or the TCP
//! stats frame. A corrupt or truncated spill file is likewise contained:
//! the touched segment is zeroed and counted (`ShardStats::spill_errors`)
//! while every resident slice keeps serving.
//!
//! **Slice-resident ownership:** [`ShardedEngine::start`] *consumes* the
//! `TableSet`; after startup the only copies of table bytes live in the
//! placement's cells (RAM or spill tier — the leader keeps counters and
//! byte accounting, and callers keep a [`TableCatalog`] for validation).
//!
//! **Live table updates (MVCC):** [`ShardedEngine::update_table`] builds
//! the next placement snapshot exactly like the rebalancer does —
//! clone → patch only the cells holding updated rows → swap the
//! `Arc<Placement>` atomically. Fused rows are re-quantized on ingest
//! through the same single-row path as [`crate::table::TableRefresher`]
//! (bit-identical to a full requantization), the monotonic snapshot
//! `version` flows through [`ShardStats`] into the stats frame, and
//! replaced cells are [`invalidated`](SliceStore::invalidate) in the
//! slice store so their stale spill bytes are unlinked (resident cells)
//! or deleted with the last old snapshot (spilled cells) — never
//! re-adopted. Batches split against one snapshot, so no request ever
//! observes a mix of two table versions.
//!
//! **Online re-quantization:** [`ShardedEngine::requantize_to`] rebuilds
//! row-groups in newly assigned formats through the same
//! clone → rebuild → swap path (identity assignments keep their exact
//! cells and tier), and [`ShardedEngine::requantize_once`] drives the
//! [`crate::quant::budget`] solver against the observed heat; the
//! rebalancer runs that pass on its own tick when
//! [`ShardConfig::precision_budget`] is set. Every rebuild goes through
//! [`crate::quant::budget::build_table`], so an online swap is bit-exact
//! vs. quantizing fresh at the assigned format offline.

use std::collections::VecDeque;
use std::io;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::PathBuf;
use std::sync::mpsc::{sync_channel, SyncSender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::coordinator::catalog::FormatTag;
use crate::coordinator::metrics::ShardStats;
use crate::coordinator::{Router, TableCatalog, TableSet};
use crate::data::trace::Request;
use crate::quant::budget::{self, GroupSpec};
use crate::quant::{GreedyQuantizer, Quantizer};
use crate::shard::exec;
use crate::shard::gate::WakeGate;
use crate::shard::load::DecayWindow;
use crate::shard::partition::{plan_partitions, RowPartition, TablePartition};
use crate::shard::slice::TableSlice;
use crate::shard::store::{SliceCell, SliceStore, SpillConfig, StoreStats};
use crate::shard::ShardConfig;
use crate::sls::KernelBackend;
use crate::table::serial::AnyTable;
use crate::table::{quantize_row_fused, EmbeddingTable, FusedTable};
use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{
    lock_ignore_poison, read_ignore_poison, write_ignore_poison, Condvar, Mutex, PoisonError,
    RwLock,
};

/// One unit of executable (and stealable) work: a whole `(slot, table)`
/// segment of a batch. Carries its placement snapshot so execution is
/// unaffected by a concurrent rebalance.
struct SubRequest {
    slot: usize,
    table: usize,
    ids: Vec<u32>,
    /// Home shard (plurality row owner / routed replica). Stealing moves
    /// the whole sub-request; execution still reads the home placement's
    /// slices, so the result is identical either way.
    home: usize,
    placement: Arc<Placement>,
    reply: SyncSender<(usize, usize, Vec<f32>)>,
}

/// An immutable routing + residency snapshot: which shards hold which
/// table slices, and which replicas answer whole-table lookups. Swapped
/// wholesale by the rebalancer; batches clone the `Arc` once at split
/// time. The cells themselves are shared (`Arc`) across snapshots, so a
/// tier transition (spill/promote) is visible to every snapshot at once.
struct Placement {
    /// Per table: the shards holding a full copy. Whole tables list their
    /// home shard (plus every replica when hot-replicated); row-wise
    /// tables list nothing (ownership is per chunk).
    replicas: Vec<Vec<usize>>,
    /// `slices[shard][table]` — the shard's slice cell, if any (RAM- or
    /// disk-tier).
    slices: Vec<Vec<Option<Arc<SliceCell>>>>,
}

impl Placement {
    /// RAM-resident bytes per shard (spilled cells cost nothing here).
    fn shard_bytes(&self) -> Vec<usize> {
        self.slices
            .iter()
            .map(|s| s.iter().flatten().map(|c| c.resident_bytes()).sum())
            .collect()
    }

    /// Logical bytes of the cells currently in the disk tier.
    fn spilled_bytes(&self) -> usize {
        self.slices
            .iter()
            .flat_map(|s| s.iter().flatten())
            .filter(|c| !c.is_resident())
            .map(|c| c.bytes())
            .sum()
    }

    fn replicated_bytes(&self, bytes_per_table: &[usize]) -> usize {
        self.replicas
            .iter()
            .enumerate()
            .map(|(t, r)| r.len().saturating_sub(1) * bytes_per_table[t])
            .sum()
    }
}

/// Rebalancer bookkeeping (guarded by one mutex that also serializes
/// passes).
struct RebalanceState {
    /// Loads at the previous tick (window deltas feed the decay).
    last_loads: Vec<u64>,
    /// Per-table exponential-decay load windows — the ranking signal
    /// (shared arithmetic and cadence with the spill policy's per-cell
    /// heat).
    windows: Vec<DecayWindow>,
    /// Consecutive non-idle ticks in which no whole table was hot.
    quiet_ticks: u32,
}

/// Cumulative counters of the runtime rebalancer.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RebalanceStats {
    /// Placement swaps performed.
    pub rebalances: u64,
    /// Whole-table replicas materialized.
    pub replicas_added: u64,
    /// Replicas retired (table went cold).
    pub replicas_retired: u64,
}

/// One entry of a re-quantization plan: rebuild a placement row-group —
/// a whole replicated table, or one row-wise chunk — in `format`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GroupAssignment {
    /// Target table id.
    pub table: usize,
    /// Row-wise chunk (shard) index. `None` covers every cell of the
    /// table: all non-empty chunks of a row-wise table, every replica of
    /// a whole one. `Some(_)` on a whole table is invalid input (whole
    /// replicas must stay byte-identical, so they can only move
    /// together).
    pub chunk: Option<usize>,
    /// Format to rebuild the group in.
    pub format: FormatTag,
}

/// What a [`ShardedEngine::requantize_once`] pass decided, did, and
/// measured — the numbers the eval/bench harnesses print.
#[derive(Clone, Copy, Debug)]
pub struct RequantOutcome {
    /// Serving version after the pass (unchanged when nothing moved).
    pub version: u64,
    /// Row-groups actually rebuilt (identity assignments are skipped).
    pub changed: usize,
    /// Payload bytes of the chosen assignment (≤ the budget).
    pub total_bytes: usize,
    /// Reference: payload bytes at uniform `int4 (FP16)`.
    pub uniform_int4_bytes: usize,
    /// Heat-weighted squared error of the chosen assignment.
    pub weighted_err: f64,
    /// Reference: heat-weighted squared error at uniform `int4 (FP16)`.
    pub uniform_int4_err: f64,
    /// Normalization `Σ heat·‖X‖²` for the L2 reports.
    pub weighted_norm: f64,
}

impl RequantOutcome {
    /// Heat-weighted normalized L2 of the committed assignment.
    pub fn weighted_l2(&self) -> f64 {
        if self.weighted_norm == 0.0 {
            0.0
        } else {
            (self.weighted_err / self.weighted_norm).sqrt()
        }
    }

    /// Heat-weighted normalized L2 of the uniform-int4 reference.
    pub fn uniform_int4_l2(&self) -> f64 {
        if self.weighted_norm == 0.0 {
            0.0
        } else {
            (self.uniform_int4_err / self.weighted_norm).sqrt()
        }
    }
}

/// Everything the workers, the rebalancer, and the leader share.
struct Core {
    partitions: Vec<TablePartition>,
    placement: RwLock<Arc<Placement>>,
    /// Per-shard work deques (owner pops the front; thieves do too, so
    /// the oldest queued work is served first either way).
    queues: Vec<Mutex<VecDeque<SubRequest>>>,
    /// Queued-count hints per shard (busiest-peer selection).
    queued: Vec<AtomicUsize>,
    total_queued: AtomicUsize,
    /// Per-shard wakeup gates (one condvar per worker; no shared
    /// notify_all, no idle polling tick). The park/wake protocol lives
    /// in [`WakeGate`] and is model-checked — see `shard::gate`.
    gates: Vec<WakeGate>,
    steal: bool,
    /// Tiered slice storage; `None` keeps every slice resident forever.
    /// MUST be declared after `placement` and `queues`: fields drop in
    /// declaration order, and the store's drop removes the (per-run
    /// default) spill directory with non-recursive `remove_dir`, which
    /// only succeeds once every cell those fields hold has dropped and
    /// deleted its spill file.
    store: Option<SliceStore>,
    stats: Vec<Mutex<ShardStats>>,
    /// Round-robin cursor for spreading lookups across replicas.
    rr: AtomicUsize,
    /// Router-observed pooled-lookup count per table.
    loads: Vec<AtomicU64>,
    offsets: Vec<usize>,
    dims: Vec<usize>,
    feature_width: usize,
    num_tables: usize,
    /// Logical bytes of the consumed set (1× the tables).
    table_bytes: usize,
    bytes_per_table: Vec<usize>,
    /// Reply-channel capacity per batch (backpressure knob).
    reply_capacity: usize,
    /// Replica budget of the runtime rebalancer.
    rebalance_budget: usize,
    /// Heat-adaptive mixed precision: global byte budget the rebalancer
    /// re-solves the per-group format assignment against on every
    /// non-idle tick (`None` = formats never change on their own).
    precision_budget: Option<usize>,
    /// Rebalancer bookkeeping; one mutex, held across a whole pass, so
    /// concurrent passes (background thread + `rebalance_once`) cannot
    /// interleave and discard each other's placements.
    rb_state: Mutex<RebalanceState>,
    rebalances: AtomicU64,
    replicas_added: AtomicU64,
    replicas_retired: AtomicU64,
    /// MVCC table-snapshot version: 1 = the initial load, +1 per
    /// committed [`ShardedEngine::update_table`] swap. Bumped under the
    /// `rb_state` mutex, after the new placement is published, so the
    /// value is monotone and never runs ahead of the data: a reader
    /// that sees `version() == v` is guaranteed the `v`-th snapshot is
    /// already serving. Stamped into every [`ShardStats`] snapshot.
    version: AtomicU64,
    /// SLS kernel backend every worker pools with, resolved once at
    /// start from [`ShardConfig::kernel_backend`]
    /// (`EMBERQ_FORCE_SCALAR` → config pin → CPU detection). Backends are
    /// bit-identical; threading the resolved value explicitly (rather
    /// than re-reading the process default per segment) keeps a pinned
    /// engine pinned even when tests run engines with different
    /// backends side by side. Stamped into every [`ShardStats`]
    /// snapshot.
    kernel: KernelBackend,
}

impl Core {
    fn num_shards(&self) -> usize {
        self.queues.len()
    }
}

/// The row-wise sharded serving engine. Sole owner of the table bytes
/// (inside its placement's slices) once started.
pub struct ShardedEngine {
    core: Arc<Core>,
    workers: Vec<JoinHandle<()>>,
    rebalancer: Option<JoinHandle<()>>,
    rb_stop: Option<Arc<(Mutex<bool>, Condvar)>>,
}

impl ShardedEngine {
    /// Partition `set` per `cfg`, carve it into per-shard slices, and
    /// start the worker pool (plus the rebalancer thread when
    /// `cfg.rebalance_interval` is set). **Consumes the set**: the
    /// placement's slices are the sole owners of the rows. Peak memory
    /// during carving is the slices cut so far plus one source table;
    /// steady state is exactly the slices.
    pub fn start(set: TableSet, cfg: &ShardConfig) -> ShardedEngine {
        let n = cfg.num_shards.max(1);
        // Resolve the kernel backend up front so a misconfigured pin
        // fails loudly at startup, not mid-serve (mirrors the spill-dir
        // policy; pre-validate with `sls::backend::resolve` for a soft
        // failure).
        let kernel = crate::sls::backend::resolve(cfg.kernel_backend)
            .unwrap_or_else(|e| panic!("resolve kernel backend: {e}"));
        let num_tables = set.num_tables();
        let rows: Vec<usize> = (0..num_tables).map(|t| set.rows_of(t)).collect();
        let offsets: Vec<usize> = (0..num_tables).map(|t| set.offset_of(t)).collect();
        let dims: Vec<usize> = (0..num_tables).map(|t| set.dim_of(t)).collect();
        let feature_width = set.feature_width();
        let table_bytes = set.size_bytes();
        let partitions = plan_partitions(&rows, n, cfg.small_table_rows);

        // Start-time hot replication: whole tables are the skew hazard
        // (one shard answers all their traffic), so the hottest of them —
        // by router-observed load, row count as the prior when none was
        // observed — get a full copy on every shard.
        let mut replicas: Vec<Vec<usize>> = partitions
            .iter()
            .map(|p| match p {
                TablePartition::Whole { shard, .. } => vec![*shard],
                TablePartition::RowWise(_) => Vec::new(),
            })
            .collect();
        if cfg.replicate_hot > 0 && n > 1 {
            // Row counts are the prior only when *no* loads were
            // observed; a partial load vector must not mix units (a
            // huge cold table would outrank a genuinely hot one).
            let loads: Vec<u64> = if cfg.hot_loads.is_empty() {
                rows.iter().map(|&r| r as u64).collect()
            } else {
                (0..num_tables)
                    .map(|t| cfg.hot_loads.get(t).copied().unwrap_or(0))
                    .collect()
            };
            let hot: Vec<usize> = Router::hottest(&loads, num_tables)
                .into_iter()
                .filter(|&t| matches!(partitions[t], TablePartition::Whole { .. }))
                .take(cfg.replicate_hot)
                .collect();
            for t in hot {
                replicas[t] = (0..n).collect();
            }
        }

        // Tiered storage: a budget (or an explicit directory) stands up
        // the slice store; otherwise every cell is untracked and stays
        // resident forever.
        let store = match (cfg.resident_budget, &cfg.spill_dir) {
            (None, None) => None,
            (budget, dir) => {
                // A defaulted temp dir is ours to delete on shutdown;
                // an operator-supplied directory is not.
                let (dir, cleanup_dir) = match dir.clone() {
                    Some(d) => (d, false),
                    None => (default_spill_dir(), true),
                };
                let spill = SpillConfig {
                    dir,
                    resident_budget: budget.unwrap_or(usize::MAX),
                    cleanup_dir,
                    io_threads: cfg.spill_io_threads,
                    prefetch_window: cfg.prefetch_window,
                };
                // A configured rebalancer drives the heat decay; only
                // without one does the store tick itself on promotions.
                // A single-shard engine never runs rebalance passes
                // (`rebalance_core` is a no-op at n < 2), so its store
                // must keep the fallback clock even when an (inert)
                // interval was configured.
                let rebalancer_ticks = cfg.rebalance_interval.is_some() && n > 1;
                let store = SliceStore::new(&spill, n, rebalancer_ticks).unwrap_or_else(|e| {
                    panic!("create spill directory {}: {e}", spill.dir.display())
                });
                Some(store)
            }
        };
        let mk_cell =
            |shard: usize, t: usize, slice: TableSlice| new_cell(&store, shard, t, slice);

        // Carve the consumed set. Whole tables *move* into their owning
        // shard (no copy; replicas, when asked for, are the only copies);
        // row-wise tables are cut per chunk and the source dropped, so
        // peak carve memory is the slices so far plus one table.
        let mut bytes_per_table = Vec::with_capacity(num_tables);
        let mut slices: Vec<Vec<Option<Arc<SliceCell>>>> =
            (0..n).map(|_| Vec::with_capacity(num_tables)).collect();
        for (t, table) in set.into_tables().into_iter().enumerate() {
            bytes_per_table.push(table.size_bytes());
            for shard in slices.iter_mut() {
                shard.push(None);
            }
            match &partitions[t] {
                TablePartition::Whole { .. } => {
                    let r = &replicas[t];
                    // Copies for all replica shards but the last; the
                    // last takes the source by move.
                    for &shard in &r[..r.len() - 1] {
                        slices[shard][t] =
                            Some(mk_cell(shard, t, TableSlice::cut(&table, 0..table.rows())));
                    }
                    let last = *r.last().expect("whole table has an owner");
                    slices[last][t] = Some(mk_cell(last, t, TableSlice::from_whole(table)));
                }
                TablePartition::RowWise(p) => {
                    for (shard, out) in slices.iter_mut().enumerate() {
                        let range = p.range_of(shard);
                        if !range.is_empty() {
                            out[t] = Some(mk_cell(shard, t, TableSlice::cut(&table, range)));
                        }
                    }
                }
            }
        }
        // With a budget below the carved bytes, the cold tail spills
        // before the first request arrives. Seed carve-time heat from
        // the router-observed prior first, so the startup eviction
        // demotes genuinely cold tables — not the hot tables (and their
        // just-materialized replicas) `hot_loads` told us about; the
        // prior decays away once real touches take over. Without loads
        // every cell ties at zero and the deterministic shard/table
        // order decides.
        if let Some(st) = &store {
            // Reconcile the spill directory before anything spills:
            // leftover `*.tmp`s and strays from an unclean shutdown are
            // deleted, and a stray whose payload is byte-identical to a
            // just-carved cell is adopted — its first demotion then
            // flips without writing (every cell is resident here, which
            // is what lets adoption hash-match against live slices).
            st.sweep_orphans();
            if !cfg.hot_loads.is_empty() {
                for shard_cells in &slices {
                    for (t, cell) in shard_cells.iter().enumerate() {
                        if let Some(cell) = cell {
                            let prior = cfg.hot_loads.get(t).copied().unwrap_or(0);
                            if prior > 0 {
                                cell.touch(prior);
                            }
                        }
                    }
                }
            }
            st.enforce();
        }

        let core = Arc::new(Core {
            partitions,
            placement: RwLock::new(Arc::new(Placement { replicas, slices })),
            queues: (0..n).map(|_| Mutex::new(VecDeque::new())).collect(),
            queued: (0..n).map(|_| AtomicUsize::new(0)).collect(),
            total_queued: AtomicUsize::new(0),
            gates: (0..n).map(|_| WakeGate::new()).collect(),
            steal: cfg.steal,
            store,
            stats: (0..n).map(|_| Mutex::new(ShardStats::default())).collect(),
            rr: AtomicUsize::new(0),
            loads: (0..num_tables).map(|_| AtomicU64::new(0)).collect(),
            offsets,
            dims,
            feature_width,
            num_tables,
            table_bytes,
            bytes_per_table,
            reply_capacity: cfg.queue_depth.max(1) * n,
            rebalance_budget: cfg.replicate_hot.max(1),
            precision_budget: cfg.precision_budget,
            rb_state: Mutex::new(RebalanceState {
                last_loads: vec![0; num_tables],
                windows: vec![DecayWindow::new(); num_tables],
                quiet_ticks: 0,
            }),
            rebalances: AtomicU64::new(0),
            replicas_added: AtomicU64::new(0),
            replicas_retired: AtomicU64::new(0),
            version: AtomicU64::new(1),
            kernel,
        });
        let workers = (0..n)
            .map(|shard| {
                let core = Arc::clone(&core);
                std::thread::Builder::new()
                    .name(format!("emberq-shard-{shard}"))
                    .spawn(move || worker_loop(shard, core))
                    .expect("spawn shard worker")
            })
            .collect();
        let (rebalancer, rb_stop) = match cfg.rebalance_interval {
            Some(interval) if n > 1 => {
                let interval = interval.max(Duration::from_millis(1));
                let stop = Arc::new((Mutex::new(false), Condvar::new()));
                let stop2 = Arc::clone(&stop);
                let core2 = Arc::clone(&core);
                let handle = std::thread::Builder::new()
                    .name("emberq-rebalance".into())
                    .spawn(move || {
                        let (flag, cv) = &*stop2;
                        let mut stop_now = lock_ignore_poison(flag);
                        loop {
                            let (guard, _) = cv
                                .wait_timeout(stop_now, interval)
                                .unwrap_or_else(PoisonError::into_inner);
                            stop_now = guard;
                            if *stop_now {
                                return;
                            }
                            drop(stop_now);
                            rebalance_core(&core2);
                            stop_now = lock_ignore_poison(flag);
                        }
                    })
                    .expect("spawn rebalancer");
                (Some(handle), Some(stop))
            }
            _ => (None, None),
        };
        ShardedEngine { core, workers, rebalancer, rb_stop }
    }

    /// Number of shards.
    pub fn num_shards(&self) -> usize {
        self.core.num_shards()
    }

    /// Width of one response vector (Σ table dims).
    pub fn feature_width(&self) -> usize {
        self.core.feature_width
    }

    /// The partition of `table`.
    pub fn partition(&self, table: usize) -> &TablePartition {
        &self.core.partitions[table]
    }

    /// Shards currently holding a full copy of `table` (len > 1 iff
    /// hot-replicated; empty for row-wise tables). A snapshot: the
    /// rebalancer may change it between calls.
    pub fn replica_shards(&self, table: usize) -> Vec<usize> {
        read_ignore_poison(&self.core.placement).replicas[table].clone()
    }

    /// Logical bytes of the consumed table set (1×).
    pub fn table_bytes(&self) -> usize {
        self.core.table_bytes
    }

    /// RAM-resident bytes per shard (each shard's RAM-tier slices,
    /// replicas included), for the current placement. Spilled slices
    /// cost nothing here — they show up in
    /// [`ShardedEngine::spilled_bytes`].
    pub fn shard_bytes(&self) -> Vec<usize> {
        read_ignore_poison(&self.core.placement).shard_bytes()
    }

    /// Logical bytes of the current placement's disk-tier slices.
    pub fn spilled_bytes(&self) -> usize {
        read_ignore_poison(&self.core.placement).spilled_bytes()
    }

    /// The resident-bytes budget, when tiered storage is enabled with a
    /// finite budget.
    pub fn resident_budget(&self) -> Option<usize> {
        self.core
            .store
            .as_ref()
            .map(SliceStore::budget)
            .filter(|&b| b != usize::MAX)
    }

    /// Cumulative tier-transition counters (`None` without tiered
    /// storage).
    pub fn store_stats(&self) -> Option<StoreStats> {
        self.core.store.as_ref().map(SliceStore::stats)
    }

    /// Demote every resident slice to the disk tier (tests and "drop
    /// caches" operations); returns how many were demoted, or `Ok(0)`
    /// without a store. Serving afterwards promotes slices back on
    /// touch, bit-exactly.
    pub fn spill_all(&self) -> io::Result<usize> {
        match &self.core.store {
            Some(st) => st.demote_all(),
            None => Ok(0),
        }
    }

    /// Stall `threads` spill I/O workers for `d` (fault injection for the
    /// chaos harness: a wedged I/O pool). Returns how many workers were
    /// stalled — `0` without tiered storage. While wedged, promotions
    /// fall back to inline reads on the requesting thread, so serving
    /// degrades in latency but never in correctness.
    pub fn wedge_spill_io(&self, d: Duration, threads: usize) -> usize {
        self.core.store.as_ref().map_or(0, |st| st.wedge_io(d, threads))
    }

    /// Bytes attributable to whole-table replication (logical: replicas
    /// count whether their cells are resident or spilled), for the
    /// current placement.
    pub fn replicated_bytes(&self) -> usize {
        read_ignore_poison(&self.core.placement).replicated_bytes(&self.core.bytes_per_table)
    }

    /// The kernel backend every worker pools with, resolved once at
    /// start (`EMBERQ_FORCE_SCALAR` → [`ShardConfig::kernel_backend`]
    /// → CPU detection).
    pub fn kernel_backend(&self) -> KernelBackend {
        self.core.kernel
    }

    /// Snapshot of each shard's service stats (cumulative since start).
    /// Poison-tolerant: readable even after a worker panic. Tier
    /// transitions (promotions/demotions/spill reads/spill errors) are
    /// folded in from the slice store, attributed to the shard owning
    /// the moved slice.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.core
            .stats
            .iter()
            .enumerate()
            .map(|(shard, s)| {
                let mut st = lock_ignore_poison(s).clone();
                st.version = self.core.version.load(Ordering::Acquire);
                st.kernel = Some(self.core.kernel);
                if let Some(store) = &self.core.store {
                    let spill = store.shard_spill(shard);
                    st.promotions = spill.promotions;
                    st.demotions = spill.demotions;
                    st.spill_read_bytes = spill.spill_read_bytes;
                    st.spill_errors = spill.spill_errors;
                    st.prefetches = spill.prefetches;
                    st.orphans_adopted = spill.orphans_adopted;
                    // Stray deletions have no owning cell, hence no
                    // shard; the sweep is a leader-side startup pass,
                    // reported on shard 0 so the totals stay exact.
                    if shard == 0 {
                        st.orphans_deleted = store.stats().orphans_deleted;
                    }
                }
                st
            })
            .collect()
    }

    /// Total sub-requests executed by a worker other than their home
    /// shard (cumulative since start).
    pub fn steal_count(&self) -> u64 {
        self.core.stats.iter().map(|s| lock_ignore_poison(s).steals).sum()
    }

    /// Cumulative counters of the runtime rebalancer.
    pub fn rebalance_stats(&self) -> RebalanceStats {
        RebalanceStats {
            rebalances: self.core.rebalances.load(Ordering::Relaxed),
            replicas_added: self.core.replicas_added.load(Ordering::Relaxed),
            replicas_retired: self.core.replicas_retired.load(Ordering::Relaxed),
        }
    }

    /// Run one rebalance pass now (what the background thread does every
    /// interval): rank tables by the load observed since the previous
    /// pass, replicate the hottest whole tables to every shard, retire
    /// replicas that went cold, and swap routing atomically. Returns
    /// whether the placement changed.
    pub fn rebalance_once(&self) -> bool {
        rebalance_core(&self.core)
    }

    /// Router-observed pooled-lookup count per table (cumulative since
    /// start) — the load signal runtime re-replication keys on.
    pub fn observed_loads(&self) -> Vec<u64> {
        self.core.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }

    /// Check the current routing against the leader's catalog: every
    /// routed replica in range and materialized with the full table,
    /// every chunk of a row-wise table present, row counts agreeing.
    pub fn validate_routing(&self, catalog: &TableCatalog) -> Result<(), String> {
        let core = &self.core;
        let n = core.num_shards();
        if catalog.num_tables() != core.num_tables {
            return Err(format!(
                "catalog has {} tables, engine has {}",
                catalog.num_tables(),
                core.num_tables
            ));
        }
        let p = read_ignore_poison(&core.placement).clone();
        for t in 0..core.num_tables {
            match &core.partitions[t] {
                TablePartition::Whole { shard, rows } => {
                    if catalog.rows_of(t) != *rows {
                        return Err(format!(
                            "table {t}: catalog rows {} != partition rows {rows}",
                            catalog.rows_of(t)
                        ));
                    }
                    let r = &p.replicas[t];
                    if r.is_empty() || !r.contains(shard) {
                        return Err(format!(
                            "table {t}: home shard {shard} missing from replica set {r:?}"
                        ));
                    }
                    for &s in r {
                        if s >= n {
                            return Err(format!("table {t}: replica shard {s} out of range"));
                        }
                        match &p.slices[s][t] {
                            Some(slice) if slice.rows() == *rows => {}
                            Some(slice) => {
                                return Err(format!(
                                    "table {t}: replica on shard {s} holds {} rows, want {rows}",
                                    slice.rows()
                                ))
                            }
                            None => {
                                return Err(format!(
                                    "table {t}: routed replica shard {s} holds no slice"
                                ))
                            }
                        }
                    }
                }
                TablePartition::RowWise(rp) => {
                    if catalog.rows_of(t) != rp.rows() {
                        return Err(format!(
                            "table {t}: catalog rows {} != partition rows {}",
                            catalog.rows_of(t),
                            rp.rows()
                        ));
                    }
                    for s in 0..n {
                        let range = rp.range_of(s);
                        match &p.slices[s][t] {
                            Some(slice) if slice.rows() == range.len() => {}
                            Some(slice) => {
                                return Err(format!(
                                    "table {t}: shard {s} chunk holds {} rows, want {}",
                                    slice.rows(),
                                    range.len()
                                ))
                            }
                            None if range.is_empty() => {}
                            None => {
                                return Err(format!(
                                    "table {t}: shard {s} missing its chunk {range:?}"
                                ))
                            }
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Pooled lookup for one request (`feature_width` floats).
    pub fn lookup(&self, req: &Request) -> Vec<f32> {
        let mut out = vec![0.0f32; self.core.feature_width];
        self.lookup_batch_into(std::slice::from_ref(req), &mut out);
        out
    }

    /// Pooled lookups for a batch; `out` is `batch × feature_width`,
    /// overwritten entirely. Safe to call concurrently; output is
    /// bit-deterministic for a given batch — each segment is computed
    /// exactly once, in id order, by whichever worker runs it.
    pub fn lookup_batch_into(&self, reqs: &[Request], out: &mut [f32]) {
        let core = &self.core;
        let fw = core.feature_width;
        assert_eq!(out.len(), reqs.len() * fw, "output buffer size mismatch");
        out.fill(0.0);
        let placement: Arc<Placement> = Arc::clone(&read_ignore_poison(&core.placement));
        let n = core.num_shards();
        let (rtx, rrx) = sync_channel(core.reply_capacity);
        let mut per_shard: Vec<Vec<SubRequest>> = (0..n).map(|_| Vec::new()).collect();
        let mut count = 0usize;
        // Scratch for plurality homing, reused across every segment of
        // the batch (row-wise partitions always span exactly `n`).
        let mut home_counts = vec![0u32; n];
        for (slot, req) in reqs.iter().enumerate() {
            assert_eq!(req.ids.len(), core.num_tables, "request table count mismatch");
            for (t, ids) in req.ids.iter().enumerate() {
                if ids.is_empty() {
                    continue;
                }
                core.loads[t].fetch_add(ids.len() as u64, Ordering::Relaxed);
                let home = match &core.partitions[t] {
                    TablePartition::Whole { .. } => {
                        // Whole tables are answered by one replica per
                        // lookup; hot-replicated tables spread lookups
                        // round-robin over byte-identical replicas, so
                        // results stay bit-identical regardless of which
                        // replica answers.
                        let r = &placement.replicas[t];
                        if r.len() > 1 {
                            r[core.rr.fetch_add(1, Ordering::Relaxed) % r.len()]
                        } else {
                            r[0]
                        }
                    }
                    TablePartition::RowWise(p) => plurality_home(p, ids, &mut home_counts),
                };
                per_shard[home].push(SubRequest {
                    slot,
                    table: t,
                    ids: ids.clone(),
                    home,
                    placement: Arc::clone(&placement),
                    reply: rtx.clone(),
                });
                count += 1;
            }
        }
        drop(rtx);
        let mut any_work = false;
        for (shard, subs) in per_shard.into_iter().enumerate() {
            if subs.is_empty() {
                continue;
            }
            any_work = true;
            let k = subs.len();
            {
                // Counters move under the same lock as the items (pop
                // decrements under it too), so they can never transiently
                // wrap below zero or claim work an empty deque lacks.
                let mut q = lock_ignore_poison(&core.queues[shard]);
                core.queued[shard].fetch_add(k, Ordering::SeqCst);
                core.total_queued.fetch_add(k, Ordering::SeqCst);
                q.extend(subs);
            }
            // Without stealing only this shard's worker can run the
            // work, so only its gate needs the wakeup.
            if !core.steal {
                wake(core, shard);
            }
        }
        // With stealing, any idle peer may pull this batch's work, so
        // every gate gets the wakeup (the per-shard gates still bound
        // the no-steal case to exactly the shards with work).
        if core.steal && any_work {
            for shard in 0..n {
                wake(core, shard);
            }
        }
        for _ in 0..count {
            // Each segment arrives exactly once; placement (not
            // accumulation) makes the output order-independent. `Err`
            // means every remaining sender vanished unexecuted (shutdown
            // race) — leave those segments zeroed rather than wedge.
            match rrx.recv() {
                Ok((slot, t, vec)) => {
                    let off = slot * fw + core.offsets[t];
                    out[off..off + vec.len()].copy_from_slice(&vec);
                }
                Err(_) => break,
            }
        }
    }

    /// Current MVCC table-snapshot version: 1 after startup, +1 per
    /// committed [`ShardedEngine::update_table`] swap. Monotone.
    pub fn version(&self) -> u64 {
        self.core.version.load(Ordering::Acquire)
    }

    /// Replace the given `(row, values)` pairs of `table` with new FP32
    /// embeddings and swap in the next placement snapshot atomically.
    /// Fused rows re-quantize on ingest (the same single-row path as
    /// [`crate::table::TableRefresher`], so the patched bytes are
    /// bit-identical to a full requantization); codebook cells
    /// re-cluster — the covering row-group's codebooks are re-trained
    /// on its patched fp32 image, bit-identical to requantizing that
    /// group from scratch (codebooks are shared across rows, so a
    /// row-local splice could not reproduce them). Returns the new
    /// version.
    ///
    /// MVCC semantics: only the cells actually holding updated rows are
    /// rebuilt — every other cell is shared by `Arc` with the previous
    /// snapshot — and batches split against exactly one snapshot, so a
    /// request sees either the old table or the new one, never a mix.
    /// In-flight batches finish on the old snapshot; its cells (and
    /// their spill files) are released when the last such batch drops.
    /// Replaced cells are retired from the slice store eagerly
    /// ([`SliceStore::invalidate`]): a stale spill file is unlinked
    /// right away when nothing can read it again, and can never be
    /// re-adopted by a later orphan sweep either way (adoption matches
    /// on content digest, and the content changed).
    ///
    /// Failure atomicity: any error — a row out of range, a wrong
    /// dimension, or a corrupt spill file hit while reading the old
    /// bytes — aborts *before* the swap.
    /// The old snapshot keeps serving, the version does not advance,
    /// and a spill error is attributed to the shard's counters under
    /// the still-current (old) version like any other read failure.
    ///
    /// Updates serialize with each other and with rebalance passes on
    /// the same mutex, so concurrent writers cannot discard each
    /// other's placements; readers are never blocked.
    pub fn update_table(
        &self,
        table: usize,
        rows: &[(u32, Vec<f32>)],
        q: &dyn Quantizer,
    ) -> io::Result<u64> {
        let core = &self.core;
        let invalid = |msg: String| io::Error::new(io::ErrorKind::InvalidInput, msg);
        if table >= core.num_tables {
            return Err(invalid(format!(
                "table {table} out of range ({} tables)",
                core.num_tables
            )));
        }
        let dim = core.dims[table];
        let table_rows = match &core.partitions[table] {
            TablePartition::Whole { rows, .. } => *rows,
            TablePartition::RowWise(p) => p.rows(),
        };
        for (id, vals) in rows {
            if *id as usize >= table_rows {
                return Err(invalid(format!(
                    "table {table}: row {id} out of range ({table_rows} rows)"
                )));
            }
            if vals.len() != dim {
                return Err(invalid(format!(
                    "table {table}: row {id} has dim {}, want {dim}",
                    vals.len()
                )));
            }
        }
        // One writer at a time: updates and rebalance passes share the
        // clone → mutate → swap critical section.
        let _swap = lock_ignore_poison(&core.rb_state);
        if rows.is_empty() {
            return Ok(core.version.load(Ordering::Acquire));
        }
        let cur: Arc<Placement> = Arc::clone(&read_ignore_poison(&core.placement));
        let replicas = cur.replicas.clone();
        let mut slices = cur.slices.clone(); // Arc clones: rows are shared, not copied
        let mut replaced: Vec<Arc<SliceCell>> = Vec::new();
        match &core.partitions[table] {
            TablePartition::Whole { .. } => {
                // Patch once from any healthy copy (replicas are
                // byte-identical; prefer a resident one so an update
                // avoids disk when it can), then give every replica
                // shard the patched slice.
                let shards = &cur.replicas[table];
                let src = resolve_whole(core, &cur, table)?;
                let pairs: Vec<(u32, &[f32])> =
                    rows.iter().map(|(i, v)| (*i, v.as_slice())).collect();
                let patched = patch_slice(&src, &pairs, q)?;
                let (last, dup) = shards.split_last().expect("whole table has an owner");
                for &s in dup {
                    let old = cur.slices[s][table]
                        .as_ref()
                        .expect("routed replica holds the table");
                    let cell = new_cell(&core.store, s, table, patched.duplicate());
                    cell.touch(old.heat_score());
                    replaced.push(Arc::clone(old));
                    slices[s][table] = Some(cell);
                }
                let old = cur.slices[*last][table]
                    .as_ref()
                    .expect("routed replica holds the table");
                let cell = new_cell(&core.store, *last, table, patched);
                cell.touch(old.heat_score());
                replaced.push(Arc::clone(old));
                slices[*last][table] = Some(cell);
            }
            TablePartition::RowWise(p) => {
                // Delta-aware: only the chunks holding updated rows are
                // rebuilt; untouched chunks stay shared with the old
                // snapshot (and keep their tier, heat, and spill file).
                let n = p.num_shards();
                let mut per_chunk: Vec<Vec<(u32, &[f32])>> = vec![Vec::new(); n];
                for (id, vals) in rows {
                    per_chunk[p.shard_of(*id)].push((*id, vals.as_slice()));
                }
                for (s, chunk_rows) in per_chunk.iter().enumerate() {
                    if chunk_rows.is_empty() {
                        continue;
                    }
                    let old = cur.slices[s][table]
                        .as_ref()
                        .expect("owning shard holds its chunk");
                    // Reading the old bytes may hit a corrupt spill
                    // file: abort before any swap (the `?`), with the
                    // error counted on the shard under the old version.
                    let src = resolve(core, old, 0)?;
                    let patched = patch_slice(&src, chunk_rows, q)?;
                    let cell = new_cell(&core.store, s, table, patched);
                    cell.touch(old.heat_score());
                    replaced.push(Arc::clone(old));
                    slices[s][table] = Some(cell);
                }
            }
        }
        *write_ignore_poison(&core.placement) = Arc::new(Placement { replicas, slices });
        // The swap is published: retire the replaced cells from the
        // spill policy (stale files unlinked now or with the last old
        // snapshot), then push the just-admitted patched cells' bytes
        // back under the budget.
        if let Some(store) = &core.store {
            for old in &replaced {
                store.invalidate(old);
            }
            store.enforce();
        }
        Ok(core.version.fetch_add(1, Ordering::AcqRel) + 1)
    }

    /// Rebuild the listed row-groups in their assigned formats and swap
    /// the next placement snapshot atomically — online re-quantization
    /// through the exact MVCC path [`ShardedEngine::update_table`]
    /// commits on. Every rebuild goes through
    /// [`crate::quant::budget::build_table`], so the swapped bytes are
    /// bit-exact vs. quantizing fresh at the assigned format offline.
    /// Groups already in their target format keep their exact cells
    /// (bytes, tier, heat, spill file); when *every* assignment is an
    /// identity the current version is returned without a bump. Returns
    /// the serving version after the pass.
    ///
    /// Failure atomicity, spill invalidation, and writer serialization
    /// are identical to `update_table`: any error (invalid plan entry,
    /// corrupt spill file under a source group) aborts before the swap,
    /// replaced cells are retired from the slice store, and the whole
    /// pass holds the rebalance mutex.
    pub fn requantize_to(
        &self,
        plan: &[GroupAssignment],
        q: &dyn Quantizer,
    ) -> io::Result<u64> {
        let core = &self.core;
        let _swap = lock_ignore_poison(&core.rb_state);
        requantize_plan(core, plan, q).map(|(v, _)| v)
    }

    /// One full heat-adaptive precision pass: collect every placement
    /// group (whole replicated tables and row-wise chunks) with its
    /// observed heat, solve the format assignment under `budget_bytes`
    /// with [`crate::quant::budget::solve`], and commit it via the
    /// [`ShardedEngine::requantize_to`] swap path. The returned
    /// [`RequantOutcome`] carries the byte/error totals the eval and
    /// bench harnesses print (heat-weighted L2 vs. the uniform-int4
    /// reference at the same budget).
    ///
    /// Heat per group is the cell's exponential-decay touch score plus
    /// the table's cumulative router-observed load apportioned by row
    /// share (untiered cells skip per-touch accounting on the pinned
    /// fast path, so the router signal is what carries the skew there),
    /// plus-one smoothed so a cold start degenerates to flat heat —
    /// and flat heat at the uniform-int4 budget degenerates to the
    /// paper's uniform `int4 (FP16)`.
    ///
    /// Background equivalent: with [`ShardConfig::precision_budget`]
    /// set, the rebalancer runs this same pass (with the paper's
    /// `GREEDY` quantizer) on every non-idle tick.
    pub fn requantize_once(
        &self,
        budget_bytes: usize,
        q: &dyn Quantizer,
    ) -> io::Result<RequantOutcome> {
        let core = &self.core;
        let _swap = lock_ignore_poison(&core.rb_state);
        requantize_budget(core, budget_bytes, q)
    }
}

impl Drop for ShardedEngine {
    fn drop(&mut self) {
        for gate in &self.core.gates {
            gate.shutdown();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        if let Some(stop) = self.rb_stop.take() {
            {
                let mut flag = lock_ignore_poison(&stop.0);
                *flag = true;
            }
            stop.1.notify_all();
        }
        if let Some(h) = self.rebalancer.take() {
            let _ = h.join();
        }
    }
}

/// Admit a slice into a placement cell: store-tracked when tiered
/// storage is on, pinned-untracked otherwise. Shared by the startup
/// carve and the rebalancer's replica materialization so the two
/// admission paths can never diverge.
fn new_cell(
    store: &Option<SliceStore>,
    shard: usize,
    table: usize,
    slice: TableSlice,
) -> Arc<SliceCell> {
    match store {
        Some(st) => st.admit(shard, table, slice),
        None => Arc::new(SliceCell::untracked(shard, table, slice)),
    }
}

/// Resolve a whole table's slice from any healthy replica: prefer a
/// resident copy (no disk touched), else promote the first readable
/// one. Errors only when every replica's spill read failed (counted on
/// the shards like any other read failure).
fn resolve_whole(core: &Core, cur: &Placement, table: usize) -> io::Result<Arc<TableSlice>> {
    let shards = &cur.replicas[table];
    let resident = shards
        .iter()
        .find_map(|&s| cur.slices[s][table].as_ref().and_then(|c| c.resident()));
    if let Some(slice) = resident {
        return Ok(slice);
    }
    let mut found = Err(io::Error::new(
        io::ErrorKind::InvalidInput,
        format!("table {table}: no replica holds a slice"),
    ));
    for &s in shards {
        let cell = cur.slices[s][table].as_ref().expect("routed replica holds the table");
        match resolve(core, cell, 0) {
            Ok(slice) => return Ok(slice),
            Err(e) => found = Err(e),
        }
    }
    found
}

/// The clone → rebuild → swap body of [`ShardedEngine::requantize_to`].
/// Caller holds the `rb_state` mutex. Returns the serving version after
/// the pass and the number of groups actually rebuilt.
fn requantize_plan(
    core: &Core,
    plan: &[GroupAssignment],
    q: &dyn Quantizer,
) -> io::Result<(u64, usize)> {
    let invalid = |msg: String| io::Error::new(io::ErrorKind::InvalidInput, msg);
    for (i, a) in plan.iter().enumerate() {
        if a.table >= core.num_tables {
            return Err(invalid(format!(
                "table {} out of range ({} tables)",
                a.table, core.num_tables
            )));
        }
        match (&core.partitions[a.table], a.chunk) {
            (TablePartition::Whole { .. }, Some(c)) => {
                return Err(invalid(format!(
                    "table {}: chunk {c} on a whole table (replicas move together; \
                     use chunk: None)",
                    a.table
                )));
            }
            (TablePartition::RowWise(p), Some(c)) => {
                if c >= p.num_shards() || p.range_of(c).is_empty() {
                    return Err(invalid(format!("table {}: chunk {c} holds no rows", a.table)));
                }
            }
            _ => {}
        }
        // Overlapping entries would make the final format order-defined
        // (and orphan an admitted cell); refuse them up front.
        for b in &plan[..i] {
            if b.table == a.table
                && (b.chunk.is_none() || a.chunk.is_none() || b.chunk == a.chunk)
            {
                return Err(invalid(format!(
                    "table {}: overlapping assignments in one plan",
                    a.table
                )));
            }
        }
    }
    let cur: Arc<Placement> = Arc::clone(&read_ignore_poison(&core.placement));
    let replicas = cur.replicas.clone();
    let mut slices = cur.slices.clone(); // Arc clones: rows are shared, not copied
    let mut replaced: Vec<Arc<SliceCell>> = Vec::new();
    let mut changed = 0usize;
    for a in plan {
        let t = a.table;
        match &core.partitions[t] {
            TablePartition::Whole { .. } => {
                // Rebuild once from any healthy copy, then hand every
                // replica shard the same bytes (replicas stay
                // byte-identical through the swap).
                let src = resolve_whole(core, &cur, t)?;
                if src.format() == a.format {
                    continue; // identity: keep the exact cells and tier
                }
                let built = TableSlice::from_parts(
                    budget::build_table(src.table(), a.format, q),
                    src.global_rows(),
                );
                let shards = &cur.replicas[t];
                let (last, dup) = shards.split_last().expect("whole table has an owner");
                for &s in dup {
                    let old = cur.slices[s][t]
                        .as_ref()
                        .expect("routed replica holds the table");
                    let cell = new_cell(&core.store, s, t, built.duplicate());
                    cell.touch(old.heat_score());
                    replaced.push(Arc::clone(old));
                    slices[s][t] = Some(cell);
                }
                let old = cur.slices[*last][t]
                    .as_ref()
                    .expect("routed replica holds the table");
                let cell = new_cell(&core.store, *last, t, built);
                cell.touch(old.heat_score());
                replaced.push(Arc::clone(old));
                slices[*last][t] = Some(cell);
                changed += 1;
            }
            TablePartition::RowWise(p) => {
                let chunks: Vec<usize> = match a.chunk {
                    Some(s) => vec![s],
                    None => {
                        (0..p.num_shards()).filter(|&s| cur.slices[s][t].is_some()).collect()
                    }
                };
                for s in chunks {
                    let old =
                        cur.slices[s][t].as_ref().expect("owning shard holds its chunk");
                    // Reading the old bytes may hit a corrupt spill
                    // file: abort before any swap (the `?`).
                    let src = resolve(core, old, 0)?;
                    if src.format() == a.format {
                        continue;
                    }
                    let built = TableSlice::from_parts(
                        budget::build_table(src.table(), a.format, q),
                        src.global_rows(),
                    );
                    let cell = new_cell(&core.store, s, t, built);
                    cell.touch(old.heat_score());
                    replaced.push(Arc::clone(old));
                    slices[s][t] = Some(cell);
                    changed += 1;
                }
            }
        }
    }
    if changed == 0 {
        // Every assignment was an identity: nothing moved, so readers
        // must not observe a version bump with unchanged bytes.
        return Ok((core.version.load(Ordering::Acquire), 0));
    }
    *write_ignore_poison(&core.placement) = Arc::new(Placement { replicas, slices });
    if let Some(store) = &core.store {
        for old in &replaced {
            store.invalidate(old);
        }
        store.enforce();
    }
    Ok((core.version.fetch_add(1, Ordering::AcqRel) + 1, changed))
}

/// The solve-and-commit body of [`ShardedEngine::requantize_once`] (and
/// the rebalancer's precision pass). Caller holds the `rb_state` mutex.
fn requantize_budget(
    core: &Core,
    budget_bytes: usize,
    q: &dyn Quantizer,
) -> io::Result<RequantOutcome> {
    let specs = collect_group_specs(core)?;
    let plan = budget::solve(&specs, budget_bytes, q)?;
    let assignments: Vec<GroupAssignment> = plan
        .assignments
        .iter()
        .map(|a| GroupAssignment { table: a.table, chunk: a.chunk, format: a.format })
        .collect();
    let weighted_norm = budget::weighted_norm(&specs);
    let (version, changed) = requantize_plan(core, &assignments, q)?;
    Ok(RequantOutcome {
        version,
        changed,
        total_bytes: plan.total_bytes,
        uniform_int4_bytes: plan.uniform_int4_bytes,
        weighted_err: plan.weighted_err,
        uniform_int4_err: plan.uniform_int4_err,
        weighted_norm,
    })
}

/// Snapshot every placement row-group as a solver [`GroupSpec`]: the
/// group's de-quantized fp32 content plus its observed heat — the
/// cell's exponential-decay touch score, plus the table's cumulative
/// router load apportioned by row share (the pinned untiered fast path
/// skips per-touch accounting, so the router signal carries the skew
/// there), plus-one smoothed so a cold start means flat heat.
fn collect_group_specs(core: &Core) -> io::Result<Vec<GroupSpec>> {
    let cur: Arc<Placement> = Arc::clone(&read_ignore_poison(&core.placement));
    let loads: Vec<u64> = core.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect();
    let mut specs = Vec::new();
    for t in 0..core.num_tables {
        match &core.partitions[t] {
            TablePartition::Whole { .. } => {
                let touch = cur.replicas[t]
                    .iter()
                    .filter_map(|&s| cur.slices[s][t].as_ref())
                    .map(|c| c.heat_score())
                    .max()
                    .unwrap_or(0);
                let src = resolve_whole(core, &cur, t)?;
                specs.push(GroupSpec {
                    table: t,
                    chunk: None,
                    heat: touch as f64 + loads[t] as f64 + 1.0,
                    data: budget::dequantize_any(src.table()),
                });
            }
            TablePartition::RowWise(p) => {
                let total_rows = p.rows() as f64;
                for s in 0..p.num_shards() {
                    let Some(cell) = cur.slices[s][t].as_ref() else { continue };
                    let src = resolve(core, cell, 0)?;
                    let share = p.range_of(s).len() as f64 / total_rows;
                    specs.push(GroupSpec {
                        table: t,
                        chunk: Some(s),
                        heat: cell.heat_score() as f64 + loads[t] as f64 * share + 1.0,
                        data: budget::dequantize_any(src.table()),
                    });
                }
            }
        }
    }
    Ok(specs)
}

/// Build a copy of `slice` with the given `(global_row, values)` pairs
/// rewritten. FP32 slices splice the floats in place; fused slices
/// re-quantize each updated row through
/// [`quantize_row_fused`] — the exact single-row arithmetic
/// `table::refresh` uses, so the patched image is bit-identical to
/// requantizing the whole table with the new rows in it. Rows not
/// listed keep their exact bytes (the quantization params are per-row,
/// so patching one row can never perturb another). Codebook slices
/// re-cluster: their codebooks are trained across rows, so a row-local
/// patch could not reproduce the full-requantization bytes — instead
/// the new rows are spliced into the covering group's fp32 image and
/// the codebooks re-trained on it (k-means here is deterministic
/// sorted Lloyd, so the result is bit-identical to quantizing the
/// patched group from scratch).
fn patch_slice(
    slice: &TableSlice,
    rows: &[(u32, &[f32])],
    q: &dyn Quantizer,
) -> io::Result<TableSlice> {
    let range = slice.global_rows();
    let dim = slice.dim();
    let table = match slice.table() {
        AnyTable::F32(t) => {
            let mut data = t.data().to_vec();
            for (id, vals) in rows {
                let local = *id as usize - range.start;
                data[local * dim..(local + 1) * dim].copy_from_slice(vals);
            }
            AnyTable::F32(EmbeddingTable::from_data(dim, data))
        }
        AnyTable::Fused(t) => {
            let mut fused = FusedTable::from_raw(
                t.rows(),
                dim,
                t.nbits(),
                t.scale_bias_dtype(),
                t.data().to_vec(),
            );
            for (id, vals) in rows {
                let local = *id as usize - range.start;
                let raw = quantize_row_fused(vals, q, t.nbits(), t.scale_bias_dtype());
                fused.patch_row(local, &raw);
            }
            AnyTable::Fused(fused)
        }
        AnyTable::Codebook(t) => {
            let mut data = t.dequantize();
            for (id, vals) in rows {
                let local = *id as usize - range.start;
                data.row_mut(local).copy_from_slice(vals);
            }
            AnyTable::Codebook(data.quantize_codebook(t.kind(), t.scale_bias_dtype()))
        }
    };
    Ok(TableSlice::from_parts(table, range))
}

/// Per-engine default spill directory under the system temp dir —
/// unique per process *and* per engine, so parallel tests (or several
/// servers in one process) never share or clobber each other's files.
fn default_spill_dir() -> PathBuf {
    static ENGINE_SEQ: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "emberq-spill-{}-{}",
        std::process::id(),
        ENGINE_SEQ.fetch_add(1, Ordering::Relaxed)
    ))
}

/// The shard owning the plurality of `ids` (ties to the lowest shard id,
/// so homing is deterministic for a given request). `counts` is caller
/// scratch of at least `p.num_shards()` entries, reused across segments
/// to keep the leader's split loop allocation-free.
fn plurality_home(p: &RowPartition, ids: &[u32], counts: &mut [u32]) -> usize {
    let counts = &mut counts[..p.num_shards()];
    counts.fill(0);
    for &id in ids {
        counts[p.shard_of(id)] += 1;
    }
    let mut best = 0usize;
    for (s, &c) in counts.iter().enumerate() {
        if c > counts[best] {
            best = s;
        }
    }
    best
}

/// Wake one shard's worker. [`WakeGate::wake`]'s lock round-trip pairs
/// with the waiter, which holds the gate's lock from its queued-counter
/// check until it parks: either the waiter saw the (already updated)
/// counters, or it is parked and the notify lands. This is what lets the
/// worker loop wait without any idle-tick backstop.
fn wake(core: &Core, shard: usize) {
    core.gates[shard].wake();
}

fn pop_queue(core: &Core, shard: usize) -> Option<SubRequest> {
    let mut q = lock_ignore_poison(&core.queues[shard]);
    let sub = q.pop_front()?;
    core.queued[shard].fetch_sub(1, Ordering::SeqCst);
    core.total_queued.fetch_sub(1, Ordering::SeqCst);
    Some(sub)
}

/// Take the next task: own deque first, then (with stealing) the busiest
/// peer's. Returns the task and whether it was stolen.
fn grab(core: &Core, shard: usize) -> Option<(SubRequest, bool)> {
    if let Some(sub) = pop_queue(core, shard) {
        return Some((sub, false));
    }
    if core.steal {
        // Single allocation-free scan for the busiest peer; the counter
        // is a racy hint re-checked by the pop itself. A failed pop just
        // returns None — the worker loop re-scans with fresh counts.
        let mut best: Option<usize> = None;
        let mut best_pending = 0usize;
        for s in (0..core.num_shards()).filter(|&s| s != shard) {
            let pending = core.queued[s].load(Ordering::SeqCst);
            if pending > best_pending {
                best_pending = pending;
                best = Some(s);
            }
        }
        if let Some(s) = best {
            if let Some(sub) = pop_queue(core, s) {
                return Some((sub, true));
            }
        }
    }
    None
}

/// Touch `cell` with `lookups` heat and return its slice, promoting it
/// from the disk tier first if needed. The promotion (and any demotions
/// its budget enforcement triggers) happens inside the slice store;
/// this worker holds its own `Arc`, so a concurrent demotion of the
/// same cell cannot pull the bytes out from under the execution.
fn resolve(core: &Core, cell: &Arc<SliceCell>, lookups: u64) -> io::Result<Arc<TableSlice>> {
    cell.touch(lookups);
    if let Some(slice) = cell.resident() {
        return Ok(slice);
    }
    let store = core.store.as_ref().expect("spilled cell implies a slice store");
    store.promote(cell)
}

/// Per-worker scratch for the tiered row-wise path (per-chunk touch
/// counts + resolved slices). Workers are long-lived threads, so these
/// two small tables are allocated once per worker and reused across
/// every segment — the serving hot path stays allocation-free beyond
/// the per-segment output vector itself.
#[derive(Default)]
struct ExecScratch {
    per_chunk: Vec<u64>,
    resolved: Vec<Option<Arc<TableSlice>>>,
}

/// Execute one segment into `out`. `Err` means a spill file could not be
/// read back (corrupt/truncated/missing): the store counted it, the
/// caller zeroes the segment, and every resident slice keeps serving.
fn execute_sub(
    core: &Core,
    sub: &SubRequest,
    out: &mut [f32],
    scratch: &mut ExecScratch,
) -> io::Result<()> {
    let t = sub.table;
    match &core.partitions[t] {
        TablePartition::Whole { .. } => {
            // Global ids are slice-local ids for a whole table; the flat
            // format kernel runs directly on the routed replica.
            let cell = sub.placement.slices[sub.home][t]
                .as_ref()
                .expect("routed replica holds the table");
            match cell.pinned() {
                // Untiered: the pinned slice — no tier lock, no heat
                // bookkeeping, no Arc clone (the pre-tiering cost).
                Some(slice) => slice.pool_with(core.kernel, &sub.ids, out),
                None => {
                    // Round-robin routing splits a replicated table's
                    // traffic 1/replicas per cell; scale the touch back
                    // up so each replica's heat tracks the *table's*
                    // aggregate rate. Otherwise the hottest table's
                    // replicas would rank colder than an unreplicated
                    // lukewarm table and be spilled first — the exact
                    // inversion the shared-heat design must prevent.
                    let replicas = sub.placement.replicas[t].len().max(1) as u64;
                    let heat = sub.ids.len() as u64 * replicas;
                    match resolve(core, cell, heat) {
                        Ok(slice) => slice.pool_with(core.kernel, &sub.ids, out),
                        Err(e) => {
                            // One replica's spill file went bad — but
                            // replicas are byte-identical, so serve from
                            // any healthy copy instead of zeroing this
                            // routed share of the table's traffic (the
                            // store already counted the error).
                            let other = sub.placement.replicas[t].iter().find_map(|&s| {
                                if s == sub.home {
                                    return None;
                                }
                                let cell = sub.placement.slices[s][t].as_ref()?;
                                resolve(core, cell, 0).ok()
                            });
                            match other {
                                Some(slice) => slice.pool_with(core.kernel, &sub.ids, out),
                                None => return Err(e),
                            }
                        }
                    }
                }
            }
        }
        TablePartition::RowWise(p) => {
            let cells = &sub.placement.slices;
            let Some(store) = &core.store else {
                // Untiered: resolve straight off the placement snapshot
                // — no per-segment scratch, exactly as before tiering
                // existed (cells outside a store are pinned).
                exec::pool_rowwise_with(
                    core.kernel,
                    p,
                    |s| {
                        cells[s][t]
                            .as_ref()
                            .expect("owning shard holds its chunk")
                            .pinned()
                            .expect("untracked cells pin their slice")
                            .table()
                    },
                    &sub.ids,
                    out,
                );
                return Ok(());
            };
            // Tiered: resolve exactly the chunks this segment touches
            // (with their true per-chunk heat) before pooling, so a
            // spilled chunk is promoted at most once per segment and
            // untouched chunks never leave the disk tier.
            let n = p.num_shards();
            exec::touch_counts(p, &sub.ids, &mut scratch.per_chunk);
            // Issue overlapping async reads for every touched spilled
            // chunk up front, so a segment spanning k spilled chunks
            // stalls for ~one read instead of k sequential ones. (A
            // single spilled chunk gains nothing from a round trip
            // through the pool; the inline read below keeps it.)
            let spilled: Vec<&Arc<SliceCell>> = (0..n)
                .filter(|&s| scratch.per_chunk[s] > 0)
                .filter_map(|s| cells[s][t].as_ref())
                .filter(|c| !c.is_resident())
                .collect();
            if spilled.len() > 1 {
                store.prefetch(spilled);
            }
            scratch.resolved.clear();
            scratch.resolved.resize(n, None);
            for s in 0..n {
                if scratch.per_chunk[s] > 0 {
                    let cell = cells[s][t].as_ref().expect("owning shard holds its chunk");
                    scratch.resolved[s] = Some(resolve(core, cell, scratch.per_chunk[s])?);
                }
            }
            let resolved = &scratch.resolved;
            exec::pool_rowwise_with(
                core.kernel,
                p,
                |s| resolved[s].as_ref().expect("touched chunks were resolved").table(),
                &sub.ids,
                out,
            );
        }
    }
    Ok(())
}

fn run_sub(core: &Core, shard: usize, sub: SubRequest, stolen: bool, scratch: &mut ExecScratch) {
    let t0 = Instant::now();
    let dim = core.dims[sub.table];
    let result = catch_unwind(AssertUnwindSafe(|| {
        let mut out = vec![0.0f32; dim];
        execute_sub(core, &sub, &mut out, scratch).map(|()| out)
    }));
    let panicked = result.is_err();
    // Record before replying so a caller that has seen the batch
    // complete also sees the stats for it.
    {
        let mut s = lock_ignore_poison(&core.stats[shard]);
        s.latency.record(t0.elapsed());
        s.tasks += 1;
        s.lookups += sub.ids.len() as u64;
        if stolen {
            s.steals += 1;
        }
        if panicked {
            s.panics += 1;
        }
    }
    // A panicked task — or one whose spill file failed to read back (the
    // store counted the spill error) — replies with an empty vector: the
    // segment stays zeroed and the batch completes instead of wedging.
    // Leader may also have given up (tests); ignore send failure.
    let payload = match result {
        Ok(Ok(out)) => out,
        Ok(Err(_)) | Err(_) => Vec::new(),
    };
    // Drop the scratch's resolved slices now rather than at the next
    // segment, so a demoted slice's memory is not pinned past its batch.
    scratch.resolved.clear();
    let _ = sub.reply.send((sub.slot, sub.table, payload));
}

fn worker_loop(shard: usize, core: Arc<Core>) {
    let mut scratch = ExecScratch::default();
    loop {
        if let Some((sub, stolen)) = grab(&core, shard) {
            run_sub(&core, shard, sub, stolen, &mut scratch);
            continue;
        }
        // Park on the gate; the predicate re-checks under the gate's
        // lock (producers take it before notifying): a non-stealing
        // worker only cares about its own deque, a stealing one about
        // any. Evaluating the check under that lock is what makes a lost
        // wakeup impossible — so the wait needs no timeout backstop.
        let parked = core.gates[shard].park_until(|| {
            if core.steal {
                core.total_queued.load(Ordering::SeqCst) > 0
            } else {
                core.queued[shard].load(Ordering::SeqCst) > 0
            }
        });
        if !parked {
            return;
        }
    }
}

/// One rebalance pass over `core`: decay-windowed load ranking → desired
/// replica sets → new placement, swapped atomically. Returns whether the
/// placement changed.
fn rebalance_core(core: &Core) -> bool {
    let n = core.num_shards();
    if n < 2 {
        return false;
    }
    // Serialize whole passes on the state mutex: the background thread
    // and a caller's `rebalance_once` must not interleave their
    // clone→compute→swap sequences, or the last writer would silently
    // discard the other pass's placement (and its freshly-copied
    // replicas) while both passes' counters accumulate.
    let mut state = lock_ignore_poison(&core.rb_state);
    let loads: Vec<u64> = core.loads.iter().map(|l| l.load(Ordering::Relaxed)).collect();
    let delta: Vec<u64> = loads
        .iter()
        .zip(state.last_loads.iter())
        .map(|(a, b)| a.saturating_sub(*b))
        .collect();
    state.last_loads = loads;
    // Fold this tick's traffic into the exponential-decay windows and
    // rank on the decayed values, not the raw last-tick delta: a bursty
    // table with a one-window gap keeps (half) its heat instead of
    // ranking stone cold, which is what stops replica thrash. The spill
    // policy's per-cell heat decays on the same cadence.
    let scores: Vec<u64> = state
        .windows
        .iter_mut()
        .zip(delta.iter())
        .map(|(w, &d)| {
            w.observe(d);
            w.tick()
        })
        .collect();
    if let Some(store) = &core.store {
        store.tick();
    }
    if delta.iter().all(|&d| d == 0) {
        return false; // idle tick: heat cooled, placement untouched
    }
    let hot: Vec<usize> = Router::hottest(&scores, core.num_tables)
        .into_iter()
        .filter(|&t| {
            scores[t] > 0 && matches!(core.partitions[t], TablePartition::Whole { .. })
        })
        .take(core.rebalance_budget)
        .collect();
    // Hysteresis, two-sided (on the decayed scores):
    // * Hot set non-empty — retire a replicated table only when its
    //   decayed heat is clearly below the selected hot set's minimum
    //   (×2 margin), never because it merely ranked one past the budget
    //   this tick; otherwise two near-equal hot tables under budget 1
    //   would flip rank on window noise and re-copy full tables every
    //   interval.
    // * Hot set empty (only row-wise traffic kept the tick non-idle) —
    //   every whole table's heat fully decayed, but replicas are only
    //   retired after two consecutive such ticks as a final backstop.
    if hot.is_empty() {
        state.quiet_ticks = state.quiet_ticks.saturating_add(1);
    } else {
        state.quiet_ticks = 0;
    }
    let retire_quiet = hot.is_empty() && state.quiet_ticks >= 2;
    let min_hot = hot.iter().map(|&t| scores[t]).min().unwrap_or(0);
    let cur: Arc<Placement> = Arc::clone(&read_ignore_poison(&core.placement));
    let mut replicas = cur.replicas.clone();
    let mut slices = cur.slices.clone(); // Arc clones: rows are shared, not copied
    let mut added = 0u64;
    let mut retired = 0u64;
    for t in 0..core.num_tables {
        let home = match &core.partitions[t] {
            TablePartition::Whole { shard, .. } => *shard,
            TablePartition::RowWise(_) => continue,
        };
        if hot.contains(&t) {
            if slices.iter().all(|ss| ss[t].is_some()) {
                continue; // already replicated everywhere
            }
            // Materialize the source once (promote() is a no-op on a
            // resident cell and reads the disk tier otherwise); an
            // unreadable spill file skips this table's replication
            // instead of failing the pass — the store counted the error.
            let src = cur.slices[home][t].as_ref().expect("home shard holds its table");
            let src_slice = match &core.store {
                Some(st) => st.promote(src).ok(),
                None => src.resident(),
            };
            let Some(src_slice) = src_slice else { continue };
            for (shard, shard_slices) in slices.iter_mut().enumerate() {
                if shard_slices[t].is_none() {
                    let cell = new_cell(&core.store, shard, t, src_slice.duplicate());
                    // A replica of the hottest table must not enter the
                    // eviction ranking stone cold — seed it with its
                    // source's heat, or the post-pass enforcement would
                    // spill exactly the data that was just replicated.
                    cell.touch(src.heat_score());
                    shard_slices[t] = Some(cell);
                    added += 1;
                }
            }
            replicas[t] = (0..n).collect();
        } else if replicas[t].len() > 1 {
            let cold = if hot.is_empty() {
                retire_quiet
            } else {
                scores[t].saturating_mul(2) < min_hot
            };
            if cold {
                for (s, shard_slices) in slices.iter_mut().enumerate() {
                    if s != home && shard_slices[t].is_some() {
                        shard_slices[t] = None;
                        retired += 1;
                    }
                }
                replicas[t] = vec![home];
            }
        }
    }
    let mut changed = false;
    if added > 0 || retired > 0 {
        *write_ignore_poison(&core.placement) = Arc::new(Placement { replicas, slices });
        // New replicas were admitted resident; push residency back under
        // the budget (retired cells free their bytes when the last
        // snapshot holding them drops).
        if added > 0 {
            if let Some(store) = &core.store {
                store.enforce();
            }
        }
        core.rebalances.fetch_add(1, Ordering::Relaxed);
        core.replicas_added.fetch_add(added, Ordering::Relaxed);
        core.replicas_retired.fetch_add(retired, Ordering::Relaxed);
        changed = true;
    }
    // Heat-adaptive precision maintenance: with a byte budget configured
    // the same pass re-solves the format assignment against the decayed
    // heat and re-quantizes drifted groups — usually a no-op (identity
    // assignments keep their cells and skip the version bump). Still
    // under the pass mutex, so the replica swap above and the precision
    // swap cannot interleave with an update. Errors are contained like
    // any other background hazard: the old formats keep serving and the
    // store counted any spill failure.
    if let Some(bytes) = core.precision_budget {
        if let Ok(out) = requantize_budget(core, bytes, &GreedyQuantizer::default()) {
            changed = changed || out.changed > 0;
        }
    }
    changed
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GreedyQuantizer;
    use crate::table::serial::AnyTable;
    use crate::table::{EmbeddingTable, ScaleBiasDtype};

    fn f32_set(num_tables: usize, rows: usize, dim: usize) -> TableSet {
        TableSet::new(
            (0..num_tables)
                .map(|t| AnyTable::F32(EmbeddingTable::randn(rows, dim, 9100 + t as u64)))
                .collect(),
        )
    }

    #[test]
    fn single_shard_matches_pool_bitwise() {
        let set = f32_set(3, 40, 8);
        let reference = f32_set(3, 40, 8);
        let engine =
            ShardedEngine::start(set, &ShardConfig { num_shards: 1, ..Default::default() });
        let req = Request { ids: vec![vec![0, 7, 7, 39], vec![], vec![12]] };
        let got = engine.lookup(&req);
        for (t, ids) in req.ids.iter().enumerate() {
            let mut want = vec![0.0f32; 8];
            reference.pool(t, ids, &mut want);
            assert_eq!(&got[t * 8..(t + 1) * 8], want.as_slice(), "table {t}");
        }
    }

    #[test]
    fn split_segments_are_bit_exact_across_shards() {
        let set = f32_set(1, 16, 4);
        let reference = f32_set(1, 16, 4);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig { num_shards: 4, small_table_rows: 0, ..Default::default() },
        );
        // ids deliberately span all four chunks ([0,4) [4,8) [8,12) [12,16)):
        // chunked execution must still equal the flat kernel bit for bit.
        let ids = vec![0u32, 5, 10, 15, 3, 12];
        let got = engine.lookup(&Request { ids: vec![ids.clone()] });
        let mut want = vec![0.0f32; 4];
        reference.pool(0, &ids, &mut want);
        assert_eq!(got, want);
    }

    #[test]
    fn fused_tables_serve_through_shards() {
        let fp32: Vec<EmbeddingTable> =
            (0..2).map(|t| EmbeddingTable::randn(30, 8, 9200 + t)).collect();
        let mk = || {
            TableSet::new(
                fp32.iter()
                    .map(|t| {
                        AnyTable::Fused(t.quantize_fused(
                            &GreedyQuantizer::default(),
                            4,
                            ScaleBiasDtype::F16,
                        ))
                    })
                    .collect(),
            )
        };
        let reference = mk();
        let engine = ShardedEngine::start(
            mk(),
            &ShardConfig { num_shards: 3, small_table_rows: 0, ..Default::default() },
        );
        let req = Request { ids: vec![vec![29, 0, 14], vec![7, 7]] };
        let got = engine.lookup(&req);
        for (t, ids) in req.ids.iter().enumerate() {
            let mut want = vec![0.0f32; 8];
            reference.pool(t, ids, &mut want);
            assert_eq!(&got[t * 8..(t + 1) * 8], want.as_slice(), "table {t}");
        }
    }

    #[test]
    fn batch_slots_stay_separated() {
        let set = f32_set(2, 20, 4);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..Default::default() },
        );
        let reqs: Vec<Request> = (0..5)
            .map(|i| Request { ids: vec![vec![i as u32], vec![19 - i as u32]] })
            .collect();
        let mut batch = vec![0.0f32; 5 * 8];
        engine.lookup_batch_into(&reqs, &mut batch);
        for (s, req) in reqs.iter().enumerate() {
            assert_eq!(&batch[s * 8..(s + 1) * 8], engine.lookup(req).as_slice(), "slot {s}");
        }
    }

    #[test]
    fn stale_output_buffer_is_overwritten() {
        let set = f32_set(1, 10, 4);
        let engine =
            ShardedEngine::start(set, &ShardConfig { num_shards: 2, ..Default::default() });
        let mut out = vec![7.0f32; 4];
        engine.lookup_batch_into(
            std::slice::from_ref(&Request { ids: vec![vec![]] }),
            &mut out,
        );
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn residency_is_exactly_the_table_bytes() {
        // The slice-resident invariant: the slices hold 1× the table
        // bytes (f32/fused carving is byte-exact), nothing retained
        // elsewhere.
        let set = f32_set(3, 200, 8);
        let logical = set.size_bytes();
        let engine = ShardedEngine::start(
            set,
            &ShardConfig { num_shards: 4, small_table_rows: 64, ..Default::default() },
        );
        assert_eq!(engine.table_bytes(), logical);
        assert_eq!(engine.shard_bytes().iter().sum::<usize>(), logical);
        assert_eq!(engine.replicated_bytes(), 0);
    }

    #[test]
    fn hot_replication_spreads_whole_table_traffic() {
        // One whole (small) table, replicated to both shards: both
        // workers must see tasks, and results must match the baseline
        // bitwise (replicas are byte-identical).
        let set = f32_set(1, 32, 4);
        let reference = f32_set(1, 32, 4);
        let logical = reference.size_bytes();
        let engine = ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 2,
                small_table_rows: usize::MAX, // keep the table whole
                replicate_hot: 1,
                ..Default::default()
            },
        );
        assert_eq!(engine.replica_shards(0), vec![0, 1]);
        assert_eq!(engine.replicated_bytes(), logical);
        assert_eq!(engine.shard_bytes().iter().sum::<usize>(), 2 * logical);
        for i in 0..10u32 {
            let req = Request { ids: vec![vec![i, 31 - i]] };
            let got = engine.lookup(&req);
            let mut want = vec![0.0f32; 4];
            reference.pool(0, &req.ids[0], &mut want);
            assert_eq!(got, want, "request {i}");
        }
        let stats = engine.shard_stats();
        assert!(stats[0].tasks > 0 && stats[1].tasks > 0, "both replicas must serve");
        assert_eq!(stats[0].lookups + stats[1].lookups, 20);
        assert_eq!(engine.observed_loads(), vec![20]);
    }

    #[test]
    fn shard_stats_account_for_served_batches() {
        let set = f32_set(2, 64, 4);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..Default::default() },
        );
        let reqs: Vec<Request> = (0..6)
            .map(|i| Request { ids: vec![vec![i as u32, 63 - i as u32], vec![i as u32]] })
            .collect();
        let mut out = vec![0.0f32; 6 * 8];
        engine.lookup_batch_into(&reqs, &mut out);
        let stats = engine.shard_stats();
        let lookups: u64 = stats.iter().map(|s| s.lookups).sum();
        assert_eq!(lookups, 18); // 6 × (2 + 1)
        assert_eq!(engine.observed_loads(), vec![12, 6]);
        for s in &stats {
            assert_eq!(s.latency.count(), s.tasks);
        }
    }

    #[test]
    fn idle_workers_steal_from_the_busy_shard() {
        // One whole table homed on one shard, no replication: without
        // stealing the peer would sit idle; with it, the peer must pick
        // up queued sub-requests and results must stay bit-exact.
        let set = f32_set(1, 512, 16);
        let reference = f32_set(1, 512, 16);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 2,
                small_table_rows: usize::MAX,
                steal: true,
                ..Default::default()
            },
        );
        let reqs: Vec<Request> = (0..800)
            .map(|i| Request {
                ids: vec![(0..256).map(|j| ((i * 37 + j * 11) % 512) as u32).collect()],
            })
            .collect();
        let mut out = vec![0.0f32; reqs.len() * 16];
        for _attempt in 0..5 {
            engine.lookup_batch_into(&reqs, &mut out);
            if engine.steal_count() > 0 {
                break;
            }
        }
        for (slot, req) in reqs.iter().enumerate() {
            let mut want = vec![0.0f32; 16];
            reference.pool(0, &req.ids[0], &mut want);
            assert_eq!(&out[slot * 16..(slot + 1) * 16], want.as_slice(), "slot {slot}");
        }
        assert!(engine.steal_count() > 0, "idle worker never stole");
        let stats = engine.shard_stats();
        assert!(stats[0].tasks > 0 && stats[1].tasks > 0);
        assert_eq!(stats.iter().map(|s| s.panics).sum::<u64>(), 0);
    }

    #[test]
    fn rebalance_replicates_hot_and_retires_cold() {
        let reference = f32_set(2, 48, 4);
        let catalog = TableCatalog::of(&reference);
        let engine = ShardedEngine::start(
            f32_set(2, 48, 4),
            &ShardConfig {
                num_shards: 2,
                small_table_rows: usize::MAX, // both tables whole
                ..Default::default()
            },
        );
        assert_eq!(engine.replica_shards(0).len(), 1);
        // Idle tick: nothing observed, nothing changes.
        assert!(!engine.rebalance_once());
        // Drive table 0 hot.
        for i in 0..20u32 {
            let _ = engine.lookup(&Request { ids: vec![vec![i % 48, 47 - i % 48], vec![]] });
        }
        assert!(engine.rebalance_once());
        assert_eq!(engine.replica_shards(0), vec![0, 1]);
        assert_eq!(engine.replica_shards(1).len(), 1);
        assert!(engine.replicated_bytes() > 0);
        engine.validate_routing(&catalog).expect("routing valid after replication");
        let after = engine.rebalance_stats();
        assert_eq!(after.rebalances, 1);
        assert_eq!(after.replicas_added, 1);
        // Results unchanged by the replica (byte-identical copies).
        let req = Request { ids: vec![vec![0, 24, 47], vec![3]] };
        let got = engine.lookup(&req);
        let mut want = vec![0.0f32; 8];
        reference.pool(0, &req.ids[0], &mut want[..4]);
        reference.pool(1, &req.ids[1], &mut want[4..]);
        assert_eq!(got, want);
        // Shift the load to table 1: table 0's replica is retired.
        for i in 0..40u32 {
            let _ = engine.lookup(&Request { ids: vec![vec![], vec![i % 48, i % 7]] });
        }
        assert!(engine.rebalance_once());
        assert_eq!(engine.replica_shards(0).len(), 1);
        assert_eq!(engine.replica_shards(1), vec![0, 1]);
        let stats = engine.rebalance_stats();
        assert_eq!(stats.rebalances, 2);
        assert_eq!(stats.replicas_added, 2);
        assert_eq!(stats.replicas_retired, 1);
        engine.validate_routing(&catalog).expect("routing valid after retirement");
        assert_eq!(engine.lookup(&req), want, "results survive the swap");
    }

    #[test]
    fn poisoned_stats_mutex_does_not_cascade() {
        // A thread that panics while holding a stats mutex poisons it;
        // both the worker-side recording and the leader-side snapshot
        // must shrug that off.
        let set = f32_set(1, 16, 4);
        let engine =
            ShardedEngine::start(set, &ShardConfig { num_shards: 2, ..Default::default() });
        let core = Arc::clone(&engine.core);
        let h = std::thread::spawn(move || {
            // lint:allow(raw_lock) — deliberately raw: this test *wants*
            // the panic below to poison the mutex.
            let _guard = core.stats[0].lock().unwrap();
            panic!("poison the stats mutex");
        });
        assert!(h.join().is_err());
        assert!(engine.core.stats[0].is_poisoned());
        // Serving still records into the poisoned mutex...
        let got = engine.lookup(&Request { ids: vec![vec![1, 2, 3]] });
        assert_eq!(got.len(), 4);
        // ...and the snapshot still reads it.
        let stats = engine.shard_stats();
        assert_eq!(stats.iter().map(|s| s.lookups).sum::<u64>(), 3);
        assert_eq!(engine.steal_count(), 0);
    }

    #[test]
    fn worker_panic_is_caught_and_counted() {
        // An out-of-range id makes the kernel panic inside the worker.
        // The batch must still complete (segment zeroed), the panic must
        // be counted, and the engine must keep serving afterwards.
        let set = f32_set(2, 20, 4);
        let reference = f32_set(2, 20, 4);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..Default::default() },
        );
        let bad = Request { ids: vec![vec![9999], vec![1]] };
        let got = engine.lookup(&bad);
        assert_eq!(&got[0..4], &[0.0; 4], "panicked segment is zeroed");
        let mut want = vec![0.0f32; 4];
        reference.pool(1, &[1], &mut want);
        assert_eq!(&got[4..8], want.as_slice(), "healthy segment still served");
        assert_eq!(engine.shard_stats().iter().map(|s| s.panics).sum::<u64>(), 1);
        // The worker survived; a valid request is served exactly.
        let ok = Request { ids: vec![vec![0, 19], vec![7]] };
        let got = engine.lookup(&ok);
        let mut want = vec![0.0f32; 8];
        reference.pool(0, &ok.ids[0], &mut want[..4]);
        reference.pool(1, &ok.ids[1], &mut want[4..]);
        assert_eq!(got, want);
    }

    #[test]
    fn clean_shutdown() {
        let set = f32_set(2, 10, 4);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 4,
                steal: true,
                rebalance_interval: Some(Duration::from_millis(5)),
                ..Default::default()
            },
        );
        let _ = engine.lookup(&Request { ids: vec![vec![1], vec![2]] });
        drop(engine); // must not hang or panic
    }

    #[test]
    fn budgeted_engine_spills_and_stays_bit_exact() {
        // Budget for roughly half the tables: the cold tail spills at
        // startup, touches promote on demand, and every lookup matches
        // the fully-resident pool bitwise.
        let reference = f32_set(4, 64, 8);
        let set = f32_set(4, 64, 8);
        let logical = set.size_bytes();
        let engine = ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 2,
                small_table_rows: usize::MAX,
                resident_budget: Some(logical / 2),
                ..Default::default()
            },
        );
        assert!(engine.resident_budget().is_some());
        let resident: usize = engine.shard_bytes().iter().sum();
        assert!(resident <= logical / 2, "startup enforce: {resident} > {}", logical / 2);
        assert_eq!(resident + engine.spilled_bytes(), logical, "tiers must reconcile");
        for i in 0..12u32 {
            let req = Request {
                ids: vec![vec![i, 63 - i], vec![i], vec![2 * i], vec![i, i, 5]],
            };
            let got = engine.lookup(&req);
            let mut want = vec![0.0f32; 4 * 8];
            for (t, ids) in req.ids.iter().enumerate() {
                reference.pool(t, ids, &mut want[t * 8..(t + 1) * 8]);
            }
            assert_eq!(got, want, "request {i}");
            let resident: usize = engine.shard_bytes().iter().sum();
            assert!(resident <= logical / 2, "budget violated after request {i}");
        }
        let stats = engine.store_stats().expect("store active");
        assert!(stats.promotions > 0, "budget below total bytes must force promotions");
        assert!(stats.demotions > 0);
        assert_eq!(stats.spill_errors, 0);
        // Per-shard stats carry the tier counters.
        let per_shard = engine.shard_stats();
        assert_eq!(per_shard.iter().map(|s| s.promotions).sum::<u64>(), stats.promotions);
        assert_eq!(per_shard.iter().map(|s| s.demotions).sum::<u64>(), stats.demotions);
    }

    #[test]
    fn spill_all_then_serve_promotes_on_touch() {
        // Row-wise chunks this time: demote everything mid-stream, then
        // a spanning request promotes exactly the touched chunks back.
        // The explicit dir plays the operator role, so the engine leaves
        // it in place — the test cleans it up itself at the end.
        let dir = default_spill_dir();
        let reference = f32_set(1, 32, 4);
        let engine = ShardedEngine::start(
            f32_set(1, 32, 4),
            &ShardConfig {
                num_shards: 4,
                small_table_rows: 0,
                spill_dir: Some(dir.clone()),
                ..Default::default()
            },
        );
        let req = Request { ids: vec![vec![0, 9, 17, 31]] }; // spans all 4 chunks
        let before = engine.lookup(&req);
        let mut want = vec![0.0f32; 4];
        reference.pool(0, &req.ids[0], &mut want);
        assert_eq!(before, want);
        assert_eq!(engine.spill_all().unwrap(), 4);
        assert_eq!(engine.shard_bytes().iter().sum::<usize>(), 0);
        assert_eq!(engine.spilled_bytes(), engine.table_bytes());
        assert_eq!(engine.lookup(&req), want, "post-spill serving must be bit-exact");
        assert_eq!(engine.store_stats().unwrap().promotions, 4);
        // A narrow request touches (and promotes) only its own chunk.
        let narrow = Request { ids: vec![vec![2, 5]] };
        engine.spill_all().unwrap();
        let mut want_narrow = vec![0.0f32; 4];
        reference.pool(0, &narrow.ids[0], &mut want_narrow);
        assert_eq!(engine.lookup(&narrow), want_narrow);
        assert_eq!(
            engine.store_stats().unwrap().promotions,
            5,
            "untouched chunks must stay spilled"
        );
        drop(engine); // cells delete their files; the dir is ours to remove
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn hot_loads_seed_the_startup_eviction() {
        // A budget below the carved bytes must spill the *cold* tables
        // at startup when a router-observed prior is available — not
        // the known-hot table by index order.
        let set = f32_set(3, 64, 8);
        let logical = set.size_bytes();
        let engine = ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 2,
                small_table_rows: usize::MAX,
                hot_loads: vec![5, 1000, 10], // table 1 is the hot one
                resident_budget: Some(logical / 3), // room for one table
                ..Default::default()
            },
        );
        // Touching the hot table costs no promotion: it stayed resident.
        let _ = engine.lookup(&Request { ids: vec![vec![], vec![0, 1], vec![]] });
        assert_eq!(engine.store_stats().unwrap().promotions, 0, "hot table was spilled");
        // Touching a cold table pays the promotion it was spilled into.
        let _ = engine.lookup(&Request { ids: vec![vec![0], vec![], vec![]] });
        assert_eq!(engine.store_stats().unwrap().promotions, 1);
    }

    #[test]
    fn wakeups_are_prompt_without_an_idle_tick() {
        // The lost-wakeup regression test for the per-shard gates. All
        // traffic targets one whole table on one shard, so only that
        // shard's gate is ever notified; each lookup starts from a fully
        // idle pool. The old scheme relied on a 20 ms idle polling tick
        // as a lost-wakeup backstop — a port that dropped a notification
        // (notifying before the counter update, or skipping the gate
        // lock) would stall every lookup up to a full tick (≥ 4 s here)
        // or, without the tick, hang forever. The watchdog turns a hang
        // into a failure; the elapsed bound turns tick-scale stalls into
        // one.
        let set = f32_set(1, 32, 4);
        let engine = Arc::new(ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 4,
                small_table_rows: usize::MAX,
                ..Default::default()
            },
        ));
        let (tx, rx) = std::sync::mpsc::channel();
        let eng = Arc::clone(&engine);
        std::thread::spawn(move || {
            let t0 = Instant::now();
            for i in 0..200u32 {
                let _ = eng.lookup(&Request { ids: vec![vec![i % 32]] });
                // Let the worker park again so every lookup exercises the
                // park → notify → wake path, not a busy worker.
                std::thread::yield_now();
            }
            let _ = tx.send(t0.elapsed());
        });
        let elapsed = rx
            .recv_timeout(Duration::from_secs(30))
            .expect("lookups wedged: a wakeup was lost and no idle tick masks it");
        assert!(
            elapsed < Duration::from_secs(4),
            "idle-tick-scale stalls crept back in: 200 lookups took {elapsed:?}"
        );
    }

    #[test]
    fn update_table_is_bit_exact_and_bumps_version() {
        // Row-wise f32: patch rows in two different chunks, leave the
        // rest untouched, and compare spanning lookups against a freshly
        // built reference set holding the same patched rows.
        let q = GreedyQuantizer::default();
        let engine = ShardedEngine::start(
            f32_set(1, 32, 4),
            &ShardConfig { num_shards: 4, small_table_rows: 0, ..Default::default() },
        );
        assert_eq!(engine.version(), 1);
        let a = vec![1.5f32, -2.0, 0.25, 8.0];
        let b = vec![-0.5f32, 3.0, 3.0, -1.0];
        let mut master = EmbeddingTable::randn(32, 4, 9100);
        master.row_mut(3).copy_from_slice(&a);
        master.row_mut(20).copy_from_slice(&b);
        let reference = TableSet::new(vec![AnyTable::F32(master)]);
        let v = engine.update_table(0, &[(3, a), (20, b)], &q).unwrap();
        assert_eq!(v, 2);
        assert_eq!(engine.version(), 2);
        let req = Request { ids: vec![vec![3, 20, 0, 31, 9]] }; // spans all chunks
        let mut want = vec![0.0f32; 4];
        reference.pool(0, &req.ids[0], &mut want);
        assert_eq!(engine.lookup(&req), want, "patched rows must serve bit-exactly");
        // An empty update is a no-op: same version back, no bump.
        assert_eq!(engine.update_table(0, &[], &q).unwrap(), 2);
        assert_eq!(engine.version(), 2);
        // The version flows through the stats snapshot.
        assert!(engine.shard_stats().iter().all(|s| s.version == 2));
    }

    #[test]
    fn fused_update_is_bit_identical_to_full_requantization() {
        // Whole fused table replicated to both shards: the on-ingest
        // single-row quantization must make every replica byte-equal to
        // quantizing the patched FP32 master from scratch.
        let q = GreedyQuantizer::default();
        let mut master = EmbeddingTable::randn(30, 8, 9300);
        let engine = ShardedEngine::start(
            TableSet::new(vec![AnyTable::Fused(master.quantize_fused(
                &q,
                4,
                ScaleBiasDtype::F16,
            ))]),
            &ShardConfig {
                num_shards: 2,
                small_table_rows: usize::MAX,
                replicate_hot: 1,
                ..Default::default()
            },
        );
        assert_eq!(engine.replica_shards(0), vec![0, 1]);
        let rows: Vec<(u32, Vec<f32>)> = [0usize, 13, 29]
            .iter()
            .map(|&r| (r as u32, (0..8).map(|d| (r as f32) * 0.1 - d as f32).collect()))
            .collect();
        for (r, vals) in &rows {
            master.row_mut(*r as usize).copy_from_slice(vals);
        }
        let reference =
            TableSet::new(vec![AnyTable::Fused(master.quantize_fused(
                &q,
                4,
                ScaleBiasDtype::F16,
            ))]);
        assert_eq!(engine.update_table(0, &rows, &q).unwrap(), 2);
        // Round-robin across replicas: every copy must hold the patch.
        for i in 0..10u32 {
            let req = Request { ids: vec![vec![0, 13, 29, i % 30]] };
            let mut want = vec![0.0f32; 8];
            reference.pool(0, &req.ids[0], &mut want);
            assert_eq!(engine.lookup(&req), want, "request {i}");
        }
    }

    #[test]
    fn update_rejects_bad_input() {
        let q = GreedyQuantizer::default();
        let engine = ShardedEngine::start(
            f32_set(1, 16, 4),
            &ShardConfig { num_shards: 2, ..Default::default() },
        );
        let ok_row = vec![0.0f32; 4];
        // Table index out of range.
        let e = engine.update_table(5, &[(0, ok_row.clone())], &q).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidInput);
        // Row out of range.
        let e = engine.update_table(0, &[(16, ok_row.clone())], &q).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidInput);
        // Wrong dimension.
        let e = engine.update_table(0, &[(0, vec![1.0; 3])], &q).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidInput);
        // No failed attempt advanced the snapshot.
        assert_eq!(engine.version(), 1);
    }

    #[test]
    fn codebook_update_reclusters_bit_identically_to_full_requantization() {
        // Codebook tables shipped read-only once; a row patch now re-runs
        // the deterministic k-means over the covering row-group inside
        // the same clone → patch → swap, so the committed table must be
        // bit-identical to re-clustering the patched FP32 state offline.
        let q = GreedyQuantizer::default();
        for kind in
            [crate::table::CodebookKind::Rowwise, crate::table::CodebookKind::TwoTier { k: 4 }]
        {
            let master = EmbeddingTable::randn(24, 8, 9450);
            let cb = master.quantize_codebook(kind, ScaleBiasDtype::F32);
            let engine = ShardedEngine::start(
                TableSet::new(vec![AnyTable::Codebook(cb.clone())]),
                &ShardConfig {
                    num_shards: 2,
                    small_table_rows: usize::MAX,
                    replicate_hot: 1,
                    ..Default::default()
                },
            );
            assert_eq!(engine.replica_shards(0), vec![0, 1]);
            let rows: Vec<(u32, Vec<f32>)> = [1u32, 17]
                .iter()
                .map(|&r| (r, (0..8).map(|d| r as f32 * 0.3 - d as f32 * 0.7).collect()))
                .collect();
            // The oracle patches the *dequantized* current state (update
            // semantics patch served values, and codebooks are lossy),
            // then re-clusters the whole group from scratch.
            let mut patched = cb.dequantize();
            for (r, vals) in &rows {
                patched.row_mut(*r as usize).copy_from_slice(vals);
            }
            let reference = TableSet::new(vec![AnyTable::Codebook(
                patched.quantize_codebook(kind, ScaleBiasDtype::F32),
            )]);
            assert_eq!(engine.update_table(0, &rows, &q).unwrap(), 2, "{kind:?}");
            // Every replica must hold the re-clustered bits.
            for i in 0..24u32 {
                let req = Request { ids: vec![vec![i]] };
                let mut want = vec![0.0f32; 8];
                reference.pool(0, &req.ids[0], &mut want);
                assert_eq!(engine.lookup(&req), want, "{kind:?} row {i}");
            }
        }
    }

    #[test]
    fn requantize_to_swaps_bit_exact_and_is_version_gated() {
        // Carve one f32 table into four row-wise chunks, then rebuild two
        // of them in different formats through the online swap. Every
        // swapped chunk must serve bit-identically to quantizing the same
        // rows fresh offline; untouched chunks keep their exact f32 bits.
        let q = GreedyQuantizer::default();
        let engine = ShardedEngine::start(
            f32_set(1, 32, 4),
            &ShardConfig { num_shards: 4, small_table_rows: 0, ..Default::default() },
        );
        let master = EmbeddingTable::randn(32, 4, 9100);
        let chunk =
            |lo: usize, hi: usize| EmbeddingTable::from_data(4, master.data()[lo * 4..hi * 4].to_vec());
        let plan = [
            GroupAssignment {
                table: 0,
                chunk: Some(0),
                format: FormatTag::Fused { nbits: 8, scale_bias: ScaleBiasDtype::F32 },
            },
            GroupAssignment {
                table: 0,
                chunk: Some(2),
                format: FormatTag::Fused { nbits: 4, scale_bias: ScaleBiasDtype::F16 },
            },
        ];
        assert_eq!(engine.requantize_to(&plan, &q).unwrap(), 2);
        assert_eq!(engine.version(), 2);
        let ref0 = TableSet::new(vec![AnyTable::Fused(
            chunk(0, 8).quantize_fused(&q, 8, ScaleBiasDtype::F32),
        )]);
        let ref2 = TableSet::new(vec![AnyTable::Fused(
            chunk(16, 24).quantize_fused(&q, 4, ScaleBiasDtype::F16),
        )]);
        for i in 0..32u32 {
            let got = engine.lookup(&Request { ids: vec![vec![i]] });
            let mut want = vec![0.0f32; 4];
            match i {
                0..=7 => ref0.pool(0, &[i], &mut want),
                16..=23 => ref2.pool(0, &[i - 16], &mut want),
                _ => want.copy_from_slice(master.row(i as usize)),
            }
            assert_eq!(got, want, "row {i}");
        }
        // Identity re-plan: every group already holds its format — no
        // rebuild, no version bump.
        assert_eq!(engine.requantize_to(&plan, &q).unwrap(), 2);
        assert_eq!(engine.version(), 2);
        // Invalid plans are rejected before any swap.
        for bad in [
            GroupAssignment { table: 7, chunk: None, format: FormatTag::F32 },
            GroupAssignment { table: 0, chunk: Some(9), format: FormatTag::F32 },
        ] {
            let e = engine.requantize_to(&[bad], &q).unwrap_err();
            assert_eq!(e.kind(), io::ErrorKind::InvalidInput);
        }
        let overlap = [
            GroupAssignment { table: 0, chunk: None, format: FormatTag::F32 },
            GroupAssignment { table: 0, chunk: Some(1), format: FormatTag::F32 },
        ];
        let e = engine.requantize_to(&overlap, &q).unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::InvalidInput);
        assert_eq!(engine.version(), 2, "failed plans must not advance the version");
    }

    #[test]
    fn requantize_once_upgrades_hot_tables_and_beats_uniform_int4() {
        // Six whole f32 tables, traffic skewed onto table 0, budget equal
        // to uniform int4 (FP16). The solver must fund an int8 upgrade of
        // the hot table with codebook downgrades of cold ones and beat
        // uniform int4 on heat-weighted error — the PR's acceptance
        // criterion against the live engine. Sizing mirrors
        // `quant::budget`'s skewed test: the hot int4→int8 step costs
        // 256·8 B and each cold codebook downgrade frees 672 B, so the
        // five cold groups cover the upgrade with slack.
        let q = GreedyQuantizer::default();
        let engine = ShardedEngine::start(
            f32_set(6, 256, 16),
            &ShardConfig { num_shards: 2, small_table_rows: usize::MAX, ..Default::default() },
        );
        // 150 requests × 2 ids drive table 0's observed load to 300;
        // untouched tables keep the +1 smoothing floor.
        for i in 0..150u32 {
            let ids = vec![vec![i % 256, 255 - i % 256], vec![], vec![], vec![], vec![], vec![]];
            let _ = engine.lookup(&Request { ids });
        }
        let budget = 6 * 256 * (16 / 2 + 4); // uniform int4 (FP16) bytes
        let out = engine.requantize_once(budget, &q).unwrap();
        assert_eq!(out.version, 2);
        assert_eq!(engine.version(), 2);
        assert!(out.changed > 0, "f32 tables cannot fit the int4 budget unchanged");
        assert_eq!(out.uniform_int4_bytes, budget);
        assert!(out.total_bytes <= budget, "{} > {budget}", out.total_bytes);
        assert!(
            out.weighted_err < out.uniform_int4_err,
            "adaptive {} vs uniform {}",
            out.weighted_err,
            out.uniform_int4_err
        );
        assert!(out.weighted_l2() < out.uniform_int4_l2());
        // The hot table deterministically lands at int8 (fp16 tails): its
        // served rows must be bit-identical to quantizing the master
        // offline at that format.
        let master = EmbeddingTable::randn(256, 16, 9100);
        let reference = TableSet::new(vec![AnyTable::Fused(
            master.quantize_fused(&q, 8, ScaleBiasDtype::F16),
        )]);
        for i in (0..256u32).step_by(17) {
            let req = Request { ids: vec![vec![i], vec![], vec![], vec![], vec![], vec![]] };
            let mut want = vec![0.0f32; 16];
            reference.pool(0, &[i], &mut want);
            assert_eq!(&engine.lookup(&req)[..16], want.as_slice(), "hot row {i}");
        }
        // A second pass under the same budget re-solves from the current
        // (already mixed) state and must still fit and serve.
        assert!(engine.requantize_once(budget, &q).is_ok());
    }

    #[test]
    fn corrupt_spill_during_update_aborts_under_the_old_version() {
        // Regression: an update whose source chunk sits on a corrupt
        // spill file must fail *before* the swap — old snapshot keeps
        // serving, version does not advance, and the error is counted on
        // the shard's spill_errors under the old version (it must never
        // panic the updater).
        let dir = default_spill_dir();
        let q = GreedyQuantizer::default();
        let reference = f32_set(1, 32, 4);
        let engine = ShardedEngine::start(
            f32_set(1, 32, 4),
            &ShardConfig {
                num_shards: 4,
                small_table_rows: 0,
                spill_dir: Some(dir.clone()),
                ..Default::default()
            },
        );
        assert_eq!(engine.spill_all().unwrap(), 4);
        // Promote chunk 0 ([0, 8)) back so part of the table is healthy.
        let mut want = vec![0.0f32; 4];
        reference.pool(0, &[0, 5], &mut want);
        assert_eq!(engine.lookup(&Request { ids: vec![vec![0, 5]] }), want);
        // Corrupt every file still on disk, remembering the originals.
        let mut saved = Vec::new();
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            if path.extension().is_some_and(|e| e == "spill") {
                let orig = std::fs::read(&path).unwrap();
                let mut bad = orig.clone();
                let last = bad.len() - 1;
                bad[last] ^= 0xFF; // flip payload bytes: checksum mismatch
                std::fs::write(&path, &bad).unwrap();
                saved.push((path, orig));
            }
        }
        assert!(!saved.is_empty(), "spilled chunks must have files");
        // Row 9 lives in chunk 1 ([8, 16)) — spilled and now corrupt.
        let patch = vec![9.0f32, 9.0, 9.0, 9.0];
        let err = engine.update_table(0, &[(9, patch.clone())], &q).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData, "{err}");
        assert_eq!(engine.version(), 1, "failed update must not advance the version");
        let stats = engine.shard_stats();
        assert!(stats.iter().map(|s| s.spill_errors).sum::<u64>() >= 1);
        assert!(stats.iter().all(|s| s.version == 1));
        // The old snapshot still serves its healthy rows bit-exactly.
        assert_eq!(engine.lookup(&Request { ids: vec![vec![0, 5]] }), want);
        // Heal the files: the same update must now commit and serve.
        for (path, orig) in &saved {
            std::fs::write(path, orig).unwrap();
        }
        assert_eq!(engine.update_table(0, &[(9, patch.clone())], &q).unwrap(), 2);
        assert_eq!(engine.lookup(&Request { ids: vec![vec![9]] }), patch);
        drop(engine);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn update_retires_stale_spill_state() {
        // Updating a spilled chunk promotes its source, patches it, and
        // invalidates the old cell — the budget enforcement afterwards
        // must still hold resident bytes at or under the budget, and the
        // updated rows must serve bit-exactly from whichever tier they
        // land on.
        let q = GreedyQuantizer::default();
        let set = f32_set(1, 64, 8);
        let logical = set.size_bytes();
        let budget = logical / 2;
        let engine = ShardedEngine::start(
            set,
            &ShardConfig {
                num_shards: 4,
                small_table_rows: 0,
                resident_budget: Some(budget),
                ..Default::default()
            },
        );
        let mut master = EmbeddingTable::randn(64, 8, 9100);
        let rows: Vec<(u32, Vec<f32>)> =
            [2u32, 33, 63].iter().map(|&r| (r, vec![r as f32; 8])).collect();
        for (r, vals) in &rows {
            master.row_mut(*r as usize).copy_from_slice(vals);
        }
        let reference = TableSet::new(vec![AnyTable::F32(master)]);
        assert_eq!(engine.update_table(0, &rows, &q).unwrap(), 2);
        let resident: usize = engine.shard_bytes().iter().sum();
        assert!(resident <= budget, "update must re-enforce the budget: {resident} > {budget}");
        assert_eq!(resident + engine.spilled_bytes(), logical, "tiers must reconcile");
        let req = Request { ids: vec![vec![2, 33, 63, 17]] };
        let mut want = vec![0.0f32; 8];
        reference.pool(0, &req.ids[0], &mut want);
        assert_eq!(engine.lookup(&req), want);
    }
}
