//! Tier-transition primitives for the tiered slice store.
//!
//! Extracted from `shard::store`'s inline fields so the claim/notify
//! protocol exists once, on the swap-in primitives from
//! [`crate::util::sync`] — the `--cfg loom` CI leg model-checks these
//! exact types (see `rust/tests/loom_models.rs`; the distilled model
//! lives in [`crate::verify::protocol::store_transition`]).
//!
//! The store's transition protocol (PR 5):
//!
//! 1. exactly one thread wins the cell's [`ClaimFlag`] (promote or demote);
//! 2. the winner does the expensive work (spill read / serialize+rename)
//!    holding **no** lock;
//! 3. the winner flips the tier pointer, releases the claim, and calls
//!    [`TransitionSignal::notify`] — whose lock round-trip guarantees the
//!    broadcast serialises after any latecomer's check-then-wait, so a
//!    completion wakeup can never be lost.
//!
//! Model-checked guarantees: the spill file is read exactly once per
//! promotion regardless of racing threads, latecomers always observe
//! completion, and budget waits settle with residency back under budget.

use crate::util::sync::atomic::{AtomicBool, Ordering};
use crate::util::sync::{cv_wait_ignore_poison, lock_ignore_poison, Condvar, Mutex};

/// A read-once transition claim: a CAS-guarded flag that exactly one
/// thread may hold at a time. Replaces the store's raw
/// `promote_pending` / `demote_pending` atomics.
pub struct ClaimFlag(AtomicBool);

impl Default for ClaimFlag {
    fn default() -> Self {
        ClaimFlag::new()
    }
}

impl ClaimFlag {
    pub const fn new() -> Self {
        ClaimFlag(AtomicBool::new(false))
    }

    /// Try to win the claim. Returns `true` for exactly one caller until
    /// [`Self::release`] is called.
    #[must_use]
    pub fn claim(&self) -> bool {
        self.0
            .compare_exchange(false, true, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
    }

    /// Release the claim. Callers must have completed (and made visible)
    /// the tier flip first: waiters treat a clear claim as "transition
    /// finished".
    pub fn release(&self) {
        self.0.store(false, Ordering::Release);
    }

    pub fn is_claimed(&self) -> bool {
        self.0.load(Ordering::Acquire)
    }
}

/// The store-wide transition broadcast: latecomers and budget waiters
/// park here until a claimant finishes. Replaces the store's raw
/// `(Mutex<()>, Condvar)` pair.
pub struct TransitionSignal {
    lock: Mutex<()>,
    cv: Condvar,
}

impl Default for TransitionSignal {
    fn default() -> Self {
        TransitionSignal::new()
    }
}

impl TransitionSignal {
    pub const fn new() -> Self {
        TransitionSignal {
            lock: Mutex::new(()),
            cv: Condvar::new(),
        }
    }

    /// Broadcast that a transition finished. The empty critical section is
    /// load-bearing: it serialises this notify after any in-flight
    /// check-then-wait in [`Self::wait_until`], so the wakeup cannot land
    /// in the gap and be lost.
    pub fn notify(&self) {
        drop(lock_ignore_poison(&self.lock));
        self.cv.notify_all();
    }

    /// Park until `done` holds. The predicate is re-checked around every
    /// wakeup (spurious or broadcast), and evaluated under the signal
    /// lock so it serialises against [`Self::notify`].
    pub fn wait_until(&self, mut done: impl FnMut() -> bool) {
        let mut g = lock_ignore_poison(&self.lock);
        while !done() {
            g = cv_wait_ignore_poison(&self.cv, g);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn claim_is_exclusive_until_released() {
        let c = ClaimFlag::new();
        assert!(c.claim());
        assert!(!c.claim(), "second claim must lose");
        assert!(c.is_claimed());
        c.release();
        assert!(!c.is_claimed());
        assert!(c.claim(), "claim must be reusable after release");
        c.release();
    }

    #[test]
    fn racing_claims_have_exactly_one_winner() {
        use crate::util::sync::atomic::{AtomicUsize, Ordering as O};
        let c = Arc::new(ClaimFlag::new());
        let wins = Arc::new(AtomicUsize::new(0));
        let hs: Vec<_> = (0..8)
            .map(|_| {
                let (c, wins) = (c.clone(), wins.clone());
                std::thread::spawn(move || {
                    if c.claim() {
                        wins.fetch_add(1, O::SeqCst);
                    }
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert_eq!(wins.load(O::SeqCst), 1);
    }

    #[test]
    fn wait_until_observes_release_and_notify() {
        let claim = Arc::new(ClaimFlag::new());
        let sig = Arc::new(TransitionSignal::new());
        assert!(claim.claim());
        let (c2, s2) = (claim.clone(), sig.clone());
        let h = std::thread::spawn(move || {
            s2.wait_until(|| !c2.is_claimed());
        });
        // Finish the "transition": release then broadcast.
        claim.release();
        sig.notify();
        h.join().unwrap();
        assert!(!claim.is_claimed());
    }

    #[test]
    fn wait_until_with_true_predicate_returns_immediately() {
        let sig = TransitionSignal::new();
        sig.wait_until(|| true);
    }
}
