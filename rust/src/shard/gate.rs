//! Worker wakeup gate: the park/wake protocol for idle shard workers.
//!
//! Extracted from `shard::engine`'s inline `(Mutex<bool>, Condvar)` pairs
//! so the protocol exists once, on the swap-in primitives from
//! [`crate::util::sync`] — which means the `--cfg loom` CI leg model-checks
//! this exact type (see `rust/tests/loom_models.rs` and the distilled
//! model in [`crate::verify::protocol::wakeup_gate`]).
//!
//! The protocol invariant: **a wake can never be lost.** Producers publish
//! work (queue pushes + atomic counters), then call [`WakeGate::wake`],
//! which takes and drops the gate lock *before* notifying. A worker checks
//! its work counters only while holding that same lock
//! ([`WakeGate::park_until`]), so the producer's lock round-trip cannot
//! complete inside the gap between a worker's last check and its park —
//! the notify always finds either a parked worker or a worker that will
//! re-check and see the work. Model-checked exhaustively; a variant
//! without the lock round-trip is proven (by the checker) to deadlock.

use crate::util::sync::{cv_wait_ignore_poison, lock_ignore_poison, Condvar, Mutex};

/// One worker's park/wake gate.
pub struct WakeGate {
    shut: Mutex<bool>,
    cv: Condvar,
}

impl Default for WakeGate {
    fn default() -> Self {
        WakeGate::new()
    }
}

impl WakeGate {
    pub fn new() -> Self {
        WakeGate {
            shut: Mutex::new(false),
            cv: Condvar::new(),
        }
    }

    /// Wake the worker after publishing work. The empty critical section is
    /// load-bearing: it serialises this notify after any in-flight
    /// check-then-park in [`Self::park_until`].
    pub fn wake(&self) {
        drop(lock_ignore_poison(&self.shut));
        self.cv.notify_one();
    }

    /// Shut the gate and wake everyone parked on it. Idempotent.
    pub fn shutdown(&self) {
        *lock_ignore_poison(&self.shut) = true;
        self.cv.notify_all();
    }

    /// Park until `has_work` holds (returns `true`) or the gate is shut
    /// (returns `false`). `has_work` is evaluated under the gate lock;
    /// spurious wakeups are absorbed by the predicate loop.
    pub fn park_until(&self, has_work: impl Fn() -> bool) -> bool {
        let mut shut = lock_ignore_poison(&self.shut);
        loop {
            if *shut {
                return false;
            }
            if has_work() {
                return true;
            }
            shut = cv_wait_ignore_poison(&self.cv, shut);
        }
    }

    /// Whether the gate has been shut.
    pub fn is_shut(&self) -> bool {
        *lock_ignore_poison(&self.shut)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn wake_releases_parked_worker() {
        let gate = Arc::new(WakeGate::new());
        let work = Arc::new(AtomicUsize::new(0));
        let (g2, w2) = (gate.clone(), work.clone());
        let h = std::thread::spawn(move || g2.park_until(|| w2.load(Ordering::SeqCst) > 0));
        work.store(1, Ordering::SeqCst);
        gate.wake();
        assert!(h.join().unwrap(), "worker should report work, not shutdown");
    }

    #[test]
    fn shutdown_releases_parked_worker() {
        let gate = Arc::new(WakeGate::new());
        let g2 = gate.clone();
        let h = std::thread::spawn(move || g2.park_until(|| false));
        gate.shutdown();
        assert!(!h.join().unwrap(), "worker should report shutdown");
        assert!(gate.is_shut());
    }

    #[test]
    fn park_returns_immediately_when_work_already_queued() {
        let gate = WakeGate::new();
        assert!(gate.park_until(|| true));
    }

    #[test]
    fn shutdown_is_idempotent_and_sticky() {
        let gate = WakeGate::new();
        gate.shutdown();
        gate.shutdown();
        assert!(gate.is_shut());
        // Shut wins even when work is pending: drain-at-shutdown is the
        // engine's policy decision, not the gate's.
        assert!(!gate.park_until(|| true));
        assert!(!gate.park_until(|| false));
    }
}
