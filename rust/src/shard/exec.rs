//! Chunked SLS: the format kernels' exact arithmetic over a table whose
//! rows live in per-shard chunk slices.
//!
//! Why this exists: f32 addition is not associative, so merging
//! *per-shard partial sums* of a split segment can never be bit-equal to
//! the flat kernel's single accumulation — no merge order fixes that,
//! and the fused kernels additionally factor `Σ bias` out of the hot
//! loop, so even the per-row addends of a partial-sum scheme differ from
//! the flat kernel's. The engine therefore executes every pooled segment
//! **whole**, on one worker, and when the segment's ids span row chunks
//! these kernels walk the ids in original request order, resolving each
//! id to its owning chunk slice. Row bytes in a slice are byte-identical
//! to the unsharded table's rows and the accumulation loops below mirror
//! the flat kernels in `crate::sls` operation for operation — same
//! [`crate::sls::kernel`] primitives on the same [`KernelBackend`], same
//! column blocking, same bias factoring — so the result is bit-identical
//! to the unsharded pool for every shard count and every backend, with
//! or without stealing, before and after a rebalance.
//!
//! Prefetch resolves only the *next pooled id's* chunk, and every id in
//! the segment has an owning chunk the segment touches anyway, so
//! prefetching never resolves (and never promotes) an untouched chunk.
//!
//! Each `pool_*` function computes **one** segment (the flat kernels'
//! per-segment body); `tests` pin bit-equality against the flat kernels
//! per format and per backend.
//!
//! **Mixed formats.** Online re-quantization may assign different
//! formats to different row chunks of one table (hot chunks int8, cold
//! chunks int4/codebook). A segment whose ids touch chunks of more than
//! one format has no flat-kernel counterpart to be bit-identical to, so
//! it takes [`pool_mixed`]: decode each pooled row to f32 through its
//! chunk's own format and accumulate in request order — scalar,
//! backend-independent, and deterministic, which is what the chaos
//! oracle and the re-quantization bit-exactness tests pin against.
//! Single-format segments never pay for this: the check walks the ids
//! and consults a chunk's format only at shard transitions.

use crate::coordinator::catalog::FormatTag;
use crate::shard::partition::RowPartition;
use crate::sls::backend::{self, KernelBackend};
use crate::sls::kernel;
use crate::table::serial::AnyTable;
use crate::table::{CodebookTable, EmbeddingTable, FusedTable};

/// Pool `ids` (global row ids, in request order) from a row-wise
/// partitioned table into `out` (`dim` floats, overwritten). `chunk_of`
/// resolves a shard id to that shard's chunk slice of the table — a
/// closure so the caller needs no per-segment scratch allocation to
/// adapt its storage (the engine resolves straight out of its placement
/// snapshot). Bit-identical to the unsharded format kernel over the
/// same ids. Runs the process-default backend ([`backend::active`]).
pub fn pool_rowwise<'a, F>(p: &RowPartition, chunk_of: F, ids: &[u32], out: &mut [f32])
where
    F: Fn(usize) -> &'a AnyTable,
{
    pool_rowwise_with(backend::active(), p, chunk_of, ids, out);
}

/// [`pool_rowwise`] pinned to an explicit kernel backend.
pub fn pool_rowwise_with<'a, F>(
    kb: KernelBackend,
    p: &RowPartition,
    chunk_of: F,
    ids: &[u32],
    out: &mut [f32],
) where
    F: Fn(usize) -> &'a AnyTable,
{
    // Dispatch on the first *used* chunk's format. Callers with tiered
    // storage only materialize the chunks a segment actually touches, so
    // an untouched chunk — shard 0 included — must never be resolved
    // here (the mixed-format check below also only consults touched
    // chunks, at shard transitions).
    let Some(&first) = ids.first() else {
        out.fill(0.0);
        return;
    };
    let first_chunk = chunk_of(p.shard_of(first));
    let first_fmt = FormatTag::of(first_chunk);
    let mut prev_shard = p.shard_of(first);
    for &id in &ids[1..] {
        let s = p.shard_of(id);
        if s != prev_shard {
            prev_shard = s;
            if FormatTag::of(chunk_of(s)) != first_fmt {
                return pool_mixed(p, &chunk_of, ids, out);
            }
        }
    }
    match first_chunk {
        AnyTable::F32(_) => pool_f32(kb, p, &chunk_of, ids, out),
        AnyTable::Fused(f) => {
            if f.nbits() == 4 {
                pool_i4(kb, p, &chunk_of, ids, out)
            } else {
                pool_i8(kb, p, &chunk_of, ids, out)
            }
        }
        AnyTable::Codebook(_) => pool_codebook(kb, p, &chunk_of, ids, out),
    }
}

/// Count how many of `ids` each shard's chunk owns, into `counts`
/// (cleared and resized to `p.num_shards()`). This is the tiered path's
/// up-front segment resolution: the engine promotes (and prefetches)
/// exactly the chunks with a non-zero count — with their true per-chunk
/// heat — before pooling, so a spilled chunk is read at most once per
/// segment and untouched chunks never leave the disk tier.
pub fn touch_counts(p: &RowPartition, ids: &[u32], counts: &mut Vec<u64>) {
    counts.clear();
    counts.resize(p.num_shards(), 0);
    for &id in ids {
        counts[p.shard_of(id)] += 1;
    }
}

/// The mixed-format segment body: decode every pooled row to f32
/// through its chunk's own format, accumulate in original request
/// order. Pure scalar on purpose — there is no flat kernel to mirror
/// when the touched chunks disagree on format, so the canonical answer
/// is this decode-then-add order, identical on every backend.
fn pool_mixed<'a, F>(p: &RowPartition, chunk_of: &F, ids: &[u32], out: &mut [f32])
where
    F: Fn(usize) -> &'a AnyTable,
{
    let d = out.len();
    out.fill(0.0);
    let mut row = vec![0.0f32; d];
    for &id in ids {
        let local = p.local_of(id) as usize;
        match chunk_of(p.shard_of(id)) {
            AnyTable::F32(t) => row.copy_from_slice(t.row(local)),
            AnyTable::Fused(f) => f.dequantize_row_into(local, &mut row),
            AnyTable::Codebook(c) => c.dequantize_row_into(local, &mut row),
        }
        for (o, r) in out.iter_mut().zip(&row) {
            *o += r;
        }
    }
}

#[inline]
fn as_f32(t: &AnyTable) -> &EmbeddingTable {
    match t {
        AnyTable::F32(t) => t,
        _ => unreachable!("chunks of one table share its format"),
    }
}

#[inline]
fn as_fused(t: &AnyTable) -> &FusedTable {
    match t {
        AnyTable::Fused(t) => t,
        _ => unreachable!("chunks of one table share its format"),
    }
}

#[inline]
fn as_codebook(t: &AnyTable) -> &CodebookTable {
    match t {
        AnyTable::Codebook(t) => t,
        _ => unreachable!("chunks of one table share its format"),
    }
}

/// Mirror of `sls_f32_with`'s per-segment body: column-blocked wide
/// rows, prefetch of the upcoming pooled row, lane-parallel accumulate.
fn pool_f32<'a, F>(kb: KernelBackend, p: &RowPartition, chunk_of: &F, ids: &[u32], out: &mut [f32])
where
    F: Fn(usize) -> &'a AnyTable,
{
    let d = out.len();
    out.fill(0.0);
    let block = d.min(kernel::CACHE_BLOCK);
    let mut col = 0usize;
    loop {
        let hi = (col + block).min(d);
        for (i, &id) in ids.iter().enumerate() {
            if let Some(&nxt) = ids.get(i + kernel::PREFETCH_AHEAD) {
                let t = as_f32(chunk_of(p.shard_of(nxt)));
                kernel::prefetch_f32s(t.row(p.local_of(nxt) as usize));
            }
            let row = as_f32(chunk_of(p.shard_of(id))).row(p.local_of(id) as usize);
            kernel::accum_f32(kb, &mut out[col..hi], &row[col..hi]);
        }
        col = hi;
        if col >= d {
            break;
        }
    }
}

/// Mirror of `sls_i8`'s per-segment body (bias factored out of the hot
/// loop, accumulated on the first column block only, added once per
/// segment — guarded exactly like the flat kernel).
fn pool_i8<'a, F>(kb: KernelBackend, p: &RowPartition, chunk_of: &F, ids: &[u32], out: &mut [f32])
where
    F: Fn(usize) -> &'a AnyTable,
{
    let d = out.len();
    out.fill(0.0);
    let block = d.min(kernel::CACHE_BLOCK);
    let mut bias_sum = 0.0f32;
    let mut col = 0usize;
    loop {
        let hi = (col + block).min(d);
        for (i, &id) in ids.iter().enumerate() {
            if let Some(&nxt) = ids.get(i + kernel::PREFETCH_AHEAD) {
                let f = as_fused(chunk_of(p.shard_of(nxt)));
                kernel::prefetch_bytes(f.row_raw(p.local_of(nxt) as usize));
            }
            let f = as_fused(chunk_of(p.shard_of(id)));
            let raw = f.row_raw(p.local_of(id) as usize);
            let (scale, bias) = f.read_tail(raw);
            if col == 0 {
                bias_sum += bias;
            }
            kernel::accum_scaled_u8(kb, &mut out[col..hi], &raw[col..hi], scale);
        }
        col = hi;
        if col >= d {
            break;
        }
    }
    if bias_sum != 0.0 {
        kernel::add_bias(kb, out, bias_sum);
    }
}

/// Mirror of `sls_i4`'s per-segment body: de-interleaved even/odd nibble
/// accumulators, interleaved (with the factored bias) once at the end.
fn pool_i4<'a, F>(kb: KernelBackend, p: &RowPartition, chunk_of: &F, ids: &[u32], out: &mut [f32])
where
    F: Fn(usize) -> &'a AnyTable,
{
    let d = out.len();
    let packed = d / 2;
    let odd_tail = d % 2 == 1;
    let half = packed + usize::from(odd_tail);
    let mut acc_even = vec![0.0f32; half];
    let mut acc_odd = vec![0.0f32; packed];
    let mut bias_sum = 0.0f32;
    for (i, &id) in ids.iter().enumerate() {
        if let Some(&nxt) = ids.get(i + kernel::PREFETCH_AHEAD) {
            let f = as_fused(chunk_of(p.shard_of(nxt)));
            kernel::prefetch_bytes(f.row_raw(p.local_of(nxt) as usize));
        }
        let f = as_fused(chunk_of(p.shard_of(id)));
        let raw = f.row_raw(p.local_of(id) as usize);
        let (scale, bias) = f.read_tail(raw);
        bias_sum += bias;
        kernel::accum_nibbles(kb, &mut acc_even[..packed], &mut acc_odd, &raw[..packed], scale);
        if odd_tail {
            acc_even[packed] += scale * (raw[packed] & 0x0F) as f32;
        }
    }
    for b in 0..packed {
        out[2 * b] = acc_even[b] + bias_sum;
        out[2 * b + 1] = acc_odd[b] + bias_sum;
    }
    if odd_tail {
        out[d - 1] = acc_even[packed] + bias_sum;
    }
}

/// Mirror of `sls_codebook_with`'s per-segment body: direct interleaved
/// accumulation off AVX2, de-interleaved gather scratch on it. Both
/// arms keep each output element's scalar addend order.
fn pool_codebook<'a, F>(
    kb: KernelBackend,
    p: &RowPartition,
    chunk_of: &F,
    ids: &[u32],
    out: &mut [f32],
) where
    F: Fn(usize) -> &'a AnyTable,
{
    let d = out.len();
    let pairs = d / 2;
    let odd_tail = d % 2 == 1;
    if kb != KernelBackend::Avx2 {
        out.fill(0.0);
        for (i, &id) in ids.iter().enumerate() {
            if let Some(&nxt) = ids.get(i + kernel::PREFETCH_AHEAD) {
                let c = as_codebook(chunk_of(p.shard_of(nxt)));
                kernel::prefetch_bytes(c.codes_of_row(p.local_of(nxt) as usize));
            }
            let c = as_codebook(chunk_of(p.shard_of(id)));
            let local = p.local_of(id) as usize;
            let cb = c.codebook_of_row(local);
            let codes = c.codes_of_row(local);
            for b in 0..pairs {
                let byte = codes[b];
                out[2 * b] += cb[(byte & 0x0F) as usize];
                out[2 * b + 1] += cb[(byte >> 4) as usize];
            }
            if odd_tail {
                out[d - 1] += cb[(codes[pairs] & 0x0F) as usize];
            }
        }
        return;
    }
    let half = pairs + usize::from(odd_tail);
    let mut acc_even = vec![0.0f32; half];
    let mut acc_odd = vec![0.0f32; pairs];
    for (i, &id) in ids.iter().enumerate() {
        if let Some(&nxt) = ids.get(i + kernel::PREFETCH_AHEAD) {
            let c = as_codebook(chunk_of(p.shard_of(nxt)));
            kernel::prefetch_bytes(c.codes_of_row(p.local_of(nxt) as usize));
        }
        let c = as_codebook(chunk_of(p.shard_of(id)));
        let local = p.local_of(id) as usize;
        let cb = c.codebook_of_row(local);
        let codes = c.codes_of_row(local);
        kernel::accum_codebook(kb, &mut acc_even[..pairs], &mut acc_odd, &codes[..pairs], cb);
        if odd_tail {
            acc_even[pairs] += cb[(codes[pairs] & 0x0F) as usize];
        }
    }
    for b in 0..pairs {
        out[2 * b] = acc_even[b];
        out[2 * b + 1] = acc_odd[b];
    }
    if odd_tail {
        out[d - 1] = acc_even[pairs];
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::TableSet;
    use crate::quant::AsymQuantizer;
    use crate::shard::slice::TableSlice;
    use crate::sls::{SlsArgs, SlsTable};
    use crate::table::{CodebookKind, ScaleBiasDtype};
    use crate::util::Rng;

    fn table_of(fmt: usize, rows: usize, dim: usize, seed: u64) -> AnyTable {
        let t = EmbeddingTable::randn(rows, dim, seed);
        match fmt {
            0 => AnyTable::F32(t),
            1 => AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)),
            2 => AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F32)),
            3 => AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32),
            ),
            _ => AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::TwoTier { k: 3.min(rows) }, ScaleBiasDtype::F16),
            ),
        }
    }

    #[test]
    fn untouched_chunks_are_never_resolved() {
        // The tiered-storage contract: pooling must only ask for chunks
        // that own at least one id (resolving an untouched chunk would
        // promote a spilled slice for nothing). A resolver that panics
        // on any other shard proves it — prefetch included, since the
        // ids below exceed PREFETCH_AHEAD and keep the lookahead live.
        let rows = 16;
        let p = RowPartition::new(rows, 4); // chunks of 4
        let table = table_of(1, rows, 8, 0xDEC0);
        let reference = TableSet::new(vec![table_of(1, rows, 8, 0xDEC0)]);
        let slices: Vec<TableSlice> =
            (0..4).map(|s| TableSlice::cut(&table, p.range_of(s))).collect();
        let ids = vec![8u32, 11, 9, 10, 8, 11, 9]; // all inside chunk 2
        let chunk_of = |s: usize| {
            assert_eq!(s, 2, "resolved an untouched chunk");
            slices[s].table()
        };
        let mut got = vec![0.0f32; 8];
        pool_rowwise(&p, chunk_of, &ids, &mut got);
        let mut want = vec![0.0f32; 8];
        reference.pool(0, &ids, &mut want);
        assert_eq!(got, want);
        // And an empty segment resolves nothing at all (just zeroes).
        let mut out = vec![7.0f32; 8];
        pool_rowwise(&p, |_| panic!("empty segment resolved a chunk"), &[], &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn touch_counts_cover_exactly_the_owning_chunks() {
        let p = RowPartition::new(16, 4); // chunks of 4
        let mut counts = vec![99u64; 1]; // stale scratch must be replaced
        touch_counts(&p, &[0, 1, 5, 15, 15], &mut counts);
        assert_eq!(counts, vec![2, 1, 0, 2]);
        touch_counts(&p, &[], &mut counts);
        assert_eq!(counts, vec![0, 0, 0, 0]);
    }

    #[test]
    fn chunked_pool_is_bit_identical_to_flat_kernel() {
        let mut rng = Rng::new(0xC0FFEE);
        for fmt in 0..5usize {
            for shards in 1..=8usize {
                let rows = 1 + rng.below(80);
                let dim = [3usize, 4, 8, 16, 33][rng.below(5)];
                let table = table_of(fmt, rows, dim, 0xF00 + (fmt * 31 + shards) as u64);
                let reference = TableSet::new(vec![table_of(
                    fmt,
                    rows,
                    dim,
                    0xF00 + (fmt * 31 + shards) as u64,
                )]);
                let p = RowPartition::new(rows, shards);
                // Cut the chunks exactly as the engine carve does.
                let slices: Vec<Option<TableSlice>> = (0..shards)
                    .map(|s| {
                        let range = p.range_of(s);
                        (!range.is_empty()).then(|| TableSlice::cut(&table, range))
                    })
                    .collect();
                let chunk_of =
                    |s: usize| slices[s].as_ref().expect("owning shard holds its chunk").table();
                for _ in 0..12 {
                    let len = rng.below(12); // may be empty
                    let ids: Vec<u32> =
                        (0..len).map(|_| rng.below(rows) as u32).collect();
                    let mut got = vec![7.0f32; dim]; // stale garbage must vanish
                    pool_rowwise(&p, chunk_of, &ids, &mut got);
                    let mut want = vec![0.0f32; dim];
                    reference.pool(0, &ids, &mut want);
                    assert_eq!(
                        got, want,
                        "fmt={fmt} shards={shards} rows={rows} dim={dim} ids={ids:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn mixed_format_chunks_pool_deterministically_on_every_backend() {
        // Heat-adaptive assignments can leave one table's chunks in
        // different formats. The segment then takes the canonical
        // scalar fallback: decode each row through its chunk's own
        // format, accumulate in request order — the same answer on
        // every backend.
        let rows = 32;
        let dim = 16;
        let master = EmbeddingTable::randn(rows, dim, 0x3117);
        let p = RowPartition::new(rows, 4);
        let slices: Vec<TableSlice> = (0..4)
            .map(|s| {
                let r = p.range_of(s);
                let sub = EmbeddingTable::from_data(
                    dim,
                    master.data()[r.start * dim..r.end * dim].to_vec(),
                );
                let t = match s {
                    0 => AnyTable::F32(sub),
                    1 => AnyTable::Fused(sub.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F32)),
                    2 => AnyTable::Fused(sub.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)),
                    _ => AnyTable::Codebook(
                        sub.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32),
                    ),
                };
                TableSlice::from_parts(t, r)
            })
            .collect();
        let ids = [0u32, 31, 9, 17, 9, 25, 2, 12, 30];
        // The semantic definition, computed independently of pool_mixed.
        let mut want = vec![0.0f32; dim];
        let mut row = vec![0.0f32; dim];
        for &id in &ids {
            let local = p.local_of(id) as usize;
            match slices[p.shard_of(id)].table() {
                AnyTable::F32(t) => row.copy_from_slice(t.row(local)),
                AnyTable::Fused(f) => f.dequantize_row_into(local, &mut row),
                AnyTable::Codebook(c) => c.dequantize_row_into(local, &mut row),
            }
            for (w, r) in want.iter_mut().zip(&row) {
                *w += r;
            }
        }
        for kb in [KernelBackend::Scalar, backend::detected()] {
            let mut got = vec![7.0f32; dim];
            pool_rowwise_with(kb, &p, |s| slices[s].table(), &ids, &mut got);
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "kb={kb}");
            }
        }
        // A single-id segment is exactly that row's decode.
        let mut got = vec![0.0f32; dim];
        pool_rowwise(&p, |s| slices[s].table(), &[17], &mut got);
        match slices[p.shard_of(17)].table() {
            AnyTable::Fused(f) => {
                let mut want = vec![0.0f32; dim];
                f.dequantize_row_into(p.local_of(17) as usize, &mut want);
                assert_eq!(got, want);
            }
            _ => panic!("id 17 should land in the int4 chunk"),
        }
    }

    #[test]
    fn chunked_pool_matches_flat_kernel_on_every_backend() {
        // The broad sweep above runs the process default; this pins the
        // backend explicitly on both sides — scalar and best-detected
        // must reproduce the flat `_with` kernel bit for bit through
        // the chunked path, misaligned chunk boundaries included.
        for kb in [KernelBackend::Scalar, backend::detected()] {
            for fmt in 0..5usize {
                let rows = 40;
                let dim = 33;
                let table = table_of(fmt, rows, dim, 0xBAC0 + fmt as u64);
                let flat = table_of(fmt, rows, dim, 0xBAC0 + fmt as u64);
                let p = RowPartition::new(rows, 3);
                let slices: Vec<TableSlice> =
                    (0..3).map(|s| TableSlice::cut(&table, p.range_of(s))).collect();
                let ids = [1u32, 39, 7, 20, 20, 5, 13, 13, 26];
                let mut got = vec![7.0f32; dim];
                pool_rowwise_with(kb, &p, |s| slices[s].table(), &ids, &mut got);
                let view: SlsTable = match &flat {
                    AnyTable::F32(t) => SlsTable::F32(t),
                    AnyTable::Fused(t) => SlsTable::Fused(t),
                    AnyTable::Codebook(t) => SlsTable::Codebook(t),
                };
                let lengths = [ids.len() as u32];
                let args = SlsArgs::new(&ids, &lengths, rows).unwrap();
                let mut want = vec![0.0f32; dim];
                view.sls_with(kb, &args, &mut want);
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "kb={kb} fmt={fmt}");
                }
            }
        }
    }
}
