//! Tiered slice storage: hot shard slices serve from RAM, cold ones
//! spill to disk and promote back on touch — with the disk work done by
//! an **asynchronous spill I/O engine** instead of on the serving path.
//!
//! The paper shrinks embedding tables to ~14% of FP32 so production
//! models fit in memory; this module takes the next capacity step — the
//! served model no longer has to fit even its *quantized* bytes in RAM.
//! Every placement entry is a [`SliceCell`] whose tier is either
//! [`SliceTier::Resident`] (an `Arc<TableSlice>` in the table's native
//! format) or [`SliceTier::Spilled`] (a [`SpillHandle`] naming an
//! on-disk file). The [`SliceStore`] owns the policy:
//!
//! * **Spill format** — `[8B "EMBQSPL2"][global_lo u64][global_hi u64]
//!   [fmt_tag u16][payload_len u64][fnv1a64 u64][payload]` where the
//!   payload is the slice's table in the exact `table::serial` container
//!   (`EMBQTBL2`) and `fmt_tag` is [`serial::format_tag`] — the
//!   layout-revision + format of the payload, validated against both the
//!   owning cell and the decoded table on load, so a spilled slice keeps
//!   its native quantized encoding (int4+tails, codebook, fp32) byte for
//!   byte and online re-quantization can never serve a stale format. See `docs/formats.md` for the
//!   normative byte-level spec. Headers, lengths, checksum, and shape
//!   are all validated on load: a truncated or corrupted file is a clean
//!   `io::Error`, never a panic.
//! * **Streaming, crash-safe writes** — a first-time demotion streams
//!   the slice chunk by chunk through a
//!   [`serial::HashingWriter`](crate::table::serial::HashingWriter)
//!   straight into `<file>.tmp` (no full serialized payload is ever
//!   buffered in RAM), patches the header's length/checksum, and
//!   atomically renames the temp onto the final path — a *process
//!   crash* can never leave a torn write at a `.spill` path, only a
//!   `.tmp` for the next startup's [`SliceStore::sweep_orphans`] to
//!   delete. (No fsync is issued, by design: after a *power loss* the
//!   rename may be durable while the payload is not, and that torn
//!   file is caught by the checksum at read time — a clean error — and
//!   deleted by the next sweep.)
//! * **Write-once** — slices are immutable, so a slice is serialized at
//!   most once; later demotions just drop the resident `Arc` and flip
//!   the tier back to the existing file. A cell deletes its file on
//!   drop (e.g. when the rebalancer retires a replica).
//! * **Orphan sweep** — startup reconciles the spill directory against
//!   the admitted registry: leftover `*.tmp` files are deleted, a stray
//!   `*.spill` whose validated payload is byte-identical to an admitted
//!   cell's serialization is **adopted** (renamed onto the cell's
//!   reserved path, so its first demotion skips the write entirely),
//!   and everything else matching our naming scheme is deleted. Files
//!   bearing this process's run token (`process_token`) belong to
//!   live sibling stores sharing the directory and are never touched —
//!   the token folds the start time in, so a restarted process sweeps
//!   its dead predecessor's files even when the OS recycled its pid
//!   (containers restart as pid 1); files outside the
//!   `slice-<token>-<seq>.spill[.tmp]` scheme are never touched either
//!   (an operator's directory may hold unrelated data).
//! * **Admission / eviction** — every slice is admitted resident
//!   (startup carve, promotion, new replicas). Whenever residency
//!   exceeds the byte budget, the store demotes the *coldest* resident
//!   cells — ranked by the same exponential-decay
//!   [`DecayWindow`](crate::shard::load::DecayWindow) heat the
//!   rebalancer ranks tables by, ticked on the same cadence — until the
//!   budget holds. The cell that triggered the promotion is evicted only
//!   as a last resort (it is by definition the hottest thing in the
//!   room), so the post-transition residency is always `<= budget`.
//! * **Concurrency** — tier transitions serialize on the store's
//!   registry mutex, but the mutex is held only for the **cell-state
//!   flips** at the start (victim selection + claim) and end (the tier
//!   pointer swap) of a demotion; the serialization and file write in
//!   between run on a small per-store background I/O pool
//!   ([`SpillConfig::io_threads`]) with no store lock held, so promotes
//!   of *other* cells never wait out a victim's serialization. A caller
//!   whose promotion overflowed the budget waits for the demotions it
//!   commissioned (so residency is back under budget when it returns),
//!   but it waits on a condvar, not on the registry lock. The hot path
//!   only ever takes a cell's tier `RwLock` for the instant it takes to
//!   clone the resident `Arc`; in-flight executions hold their own
//!   `Arc<TableSlice>` clones, so demoting a slice mid-batch is safe.
//! * **Prefetching promotions** — [`SliceStore::prefetch`] issues
//!   overlapping async reads for a set of spilled cells (the engine
//!   calls it for every spilled chunk a segment touches, so a spanning
//!   segment pays ~one read latency instead of one per chunk).
//!   Prefetch reads jump **ahead** of queued demote writes: a serving
//!   thread may be parked on the read, while writes are background
//!   work with no latency-critical waiter. A
//!   prefetch *stages* the parsed slice on the cell; the next
//!   [`SliceStore::promote`] consumes the staged copy and installs it
//!   under the normal budget enforcement, so prefetching never bypasses
//!   the byte accounting. [`SpillConfig::prefetch_window`] additionally
//!   warms the N hottest spilled cells on every heat tick (rebalancer
//!   cadence, or the promotion-path fallback clock), so a bursty table
//!   is staged before its first miss. Staged slices nobody consumed
//!   within a whole tick are dropped.
//!
//! Duplicate work is deduplicated by two per-cell
//! [`ClaimFlag`](crate::shard::transition::ClaimFlag)s: at most one
//! thread (worker or I/O pool) reads a given cell's spill file at a time
//! (`promote_claim` — latecomers wait on the store's
//! [`TransitionSignal`](crate::shard::transition::TransitionSignal)),
//! and at most one demotes it (`demote_claim`). The claim/notify
//! protocol is model-checked exhaustively — see
//! [`crate::verify::protocol::store_transition`] and
//! `rust/tests/loom_models.rs`.

use std::collections::VecDeque;
use std::fs::{self, File};
use std::io::{self, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};
use crate::shard::transition::{ClaimFlag, TransitionSignal};
use crate::util::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use crate::util::sync::{Condvar, Mutex, PoisonError, RwLock};
use std::sync::{Arc, Weak};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::shard::load::{hottest_indices, DecayWindow};
use crate::shard::slice::TableSlice;
use crate::table::serial::{self, HashingWriter};
use crate::util::sync::{lock_ignore_poison, read_ignore_poison, write_ignore_poison};

const SPILL_MAGIC: &[u8; 8] = b"EMBQSPL2";
/// magic + global_lo + global_hi + fmt_tag + payload_len + checksum.
const SPILL_HEADER_BYTES: u64 = 8 + 8 + 8 + 2 + 8 + 8;
/// Byte offset of the `[payload_len][checksum]` pair the streaming
/// writer patches after the payload has been streamed.
const SPILL_LEN_OFFSET: u64 = 8 + 8 + 8 + 2;

/// Fallback decay cadence: when no rebalancer drives [`SliceStore::tick`]
/// (the `--resident-budget` without `--rebalance-interval` configuration),
/// promotions tick the heat themselves at most this often, so eviction
/// stays recency-weighted instead of silently degrading to all-time LFU.
const HEAT_TICK_INTERVAL: Duration = Duration::from_secs(1);

/// How long an external [`SliceStore::tick`] (a rebalance pass) keeps the
/// promotion-path fallback stood down. While external ticks keep
/// arriving inside this lease, the fallback never fires (one clock,
/// never two); once they stop for a whole lease — e.g. a one-off manual
/// `rebalance_once` poke on a budget-only engine — the fallback resumes,
/// so the heat clock can never be frozen permanently.
const EXTERNAL_CLOCK_LEASE: Duration = Duration::from_secs(5);

/// Catch-up cap for the fallback clock: after an idle gap it applies one
/// half-life per elapsed [`HEAT_TICK_INTERVAL`], at most this many (64
/// halvings zero any u64, so a longer cap would be pure waste).
const MAX_CATCHUP_TICKS: u32 = 64;

/// Globally unique spill-file suffix, so engines sharing a directory
/// (tests, multiple servers per process) can never collide or delete
/// each other's files.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Per-process run token embedded in spill-file names
/// (`slice-<token:hex>-<seq>.spill`). The orphan sweep never touches
/// files bearing the *current* token — they belong to live sibling
/// stores in this process — and sweeps everything else. A pid alone
/// cannot play this role: the OS recycles pids, and a containerized
/// server is pid 1 on *every* restart, which would make its own crash
/// recovery permanently inert. Folding the process start time in gives
/// a token that differs across restarts (even with a recycled pid) yet
/// is shared by every store in one process; distinct live pids keep
/// distinct tokens via the pid bits.
fn process_token() -> u64 {
    use std::sync::OnceLock;
    static TOKEN: OnceLock<u64> = OnceLock::new();
    *TOKEN.get_or_init(|| {
        let t = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| (d.as_secs() << 30) ^ d.subsec_nanos() as u64)
            .unwrap_or(0);
        // Pid in the high bits (concurrently-live processes differ),
        // time in the low bits (restarts differ); never 0, so crafted
        // zero-token test orphans can never match a live store.
        ((std::process::id() as u64) << 48 ^ t) | 1
    })
}

/// Tiered-storage configuration of one engine.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Directory the spill files live in (created on start).
    pub dir: PathBuf,
    /// Resident-bytes budget across all slices. `usize::MAX` admits
    /// everything and only spills on explicit demotion.
    pub resident_budget: usize,
    /// Remove `dir` itself on shutdown. Set for the per-run default
    /// temp directory; an operator-supplied `--spill-dir` is left in
    /// place (only the spill files inside it are deleted).
    pub cleanup_dir: bool,
    /// Background spill I/O pool size. `0` runs demotion writes inline
    /// on the transitioning thread (still streaming, still off the
    /// registry lock — just no overlap) and disables prefetching.
    pub io_threads: usize,
    /// Warm the N hottest spilled cells per heat tick by staging their
    /// payloads ahead of the first miss. `0` (default) disables the
    /// warmer; segment-level prefetching of touched chunks is always on
    /// when the pool exists.
    pub prefetch_window: usize,
}

/// Where a spilled slice's bytes live on disk.
#[derive(Clone, Debug)]
pub struct SpillHandle {
    path: PathBuf,
    /// Total file bytes (header + payload) — the cost of a promotion.
    file_len: u64,
}

impl SpillHandle {
    /// The spill file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total file bytes (what a promotion reads back).
    pub fn file_len(&self) -> u64 {
        self.file_len
    }
}

/// Which tier a slice currently lives in.
pub enum SliceTier {
    /// In RAM, serving directly.
    Resident(Arc<TableSlice>),
    /// On disk; a touch promotes it back.
    Spilled(SpillHandle),
}

/// One placement entry: a slice's identity + metadata (always resident)
/// and its tier (RAM or disk). Cells are shared by `Arc` across
/// placement snapshots, so a promotion is visible to every snapshot at
/// once.
pub struct SliceCell {
    shard: usize,
    table: usize,
    rows: usize,
    dim: usize,
    global_lo: usize,
    /// Logical bytes when resident (the slice's native-format payload).
    bytes: usize,
    /// [`serial::format_tag`] of the slice's table — pinned at admission
    /// so a spill file can be validated against the format the placement
    /// expects even after online re-quantization swapped siblings.
    fmt_tag: u16,
    tier: RwLock<SliceTier>,
    /// Spill-file path (assigned at admission; empty for untracked
    /// cells, which never spill).
    spill_path: PathBuf,
    /// File bytes once written; 0 = never spilled (write-once marker).
    file_len: AtomicU64,
    /// Exponential-decay touch heat — same arithmetic as the
    /// rebalancer's per-table windows, ticked on the same cadence.
    heat: Mutex<DecayWindow>,
    /// Claim flag: one thread at a time reads this cell's spill file
    /// (inline promotion or prefetch job); latecomers wait on the
    /// store's transition signal instead of duplicating the read.
    promote_claim: ClaimFlag,
    /// Claim flag: one demotion of this cell in flight at a time.
    demote_claim: ClaimFlag,
    /// A prefetched slice parked here until the next promotion consumes
    /// it (the read happened off the serving path; the *install* — and
    /// its budget enforcement — still happens on the promoting thread).
    staged: Mutex<Option<Arc<TableSlice>>>,
    /// Untracked cells pin their slice here (the tier can never change),
    /// giving the untiered engine a lock-free, clone-free resolution
    /// path identical in cost to the pre-tiering design. `None` for
    /// store-tracked cells.
    pinned: Option<Arc<TableSlice>>,
}

impl SliceCell {
    fn new(
        shard: usize,
        table: usize,
        slice: TableSlice,
        spill_path: PathBuf,
        pin: bool,
    ) -> SliceCell {
        let range = slice.global_rows();
        let rows = slice.rows();
        let dim = slice.dim();
        let bytes = slice.size_bytes();
        let fmt_tag = serial::format_tag(slice.table());
        let slice = Arc::new(slice);
        SliceCell {
            shard,
            table,
            rows,
            dim,
            global_lo: range.start,
            bytes,
            fmt_tag,
            tier: RwLock::new(SliceTier::Resident(Arc::clone(&slice))),
            spill_path,
            file_len: AtomicU64::new(0),
            heat: Mutex::new(DecayWindow::new()),
            promote_claim: ClaimFlag::new(),
            demote_claim: ClaimFlag::new(),
            staged: Mutex::new(None),
            pinned: pin.then_some(slice),
        }
    }

    /// A cell outside any store: always resident, never spills, and its
    /// slice is [`SliceCell::pinned`] for lock-free resolution. The
    /// engine uses these when tiered storage is not configured so the
    /// placement type stays uniform without taxing the hot path.
    pub fn untracked(shard: usize, table: usize, slice: TableSlice) -> SliceCell {
        SliceCell::new(shard, table, slice, PathBuf::new(), true)
    }

    /// The untracked fast path: a plain borrow of the pinned slice.
    /// `None` for store-tracked cells (their tier can change, so they
    /// must go through `resident()`/`promote()`).
    pub fn pinned(&self) -> Option<&TableSlice> {
        self.pinned.as_deref()
    }

    /// Owning shard.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Table this cell slices.
    pub fn table(&self) -> usize {
        self.table
    }

    /// Rows held (tier-independent metadata — valid while spilled, which
    /// is what lets routing validation run without touching disk).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Embedding dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Logical bytes when resident.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// [`serial::format_tag`] of the table this cell slices (pinned at
    /// admission; re-quantization admits a *new* cell, never mutates).
    pub fn fmt_tag(&self) -> u16 {
        self.fmt_tag
    }

    /// The resident slice, if this cell is in the RAM tier.
    pub fn resident(&self) -> Option<Arc<TableSlice>> {
        match &*read_ignore_poison(&self.tier) {
            SliceTier::Resident(s) => Some(Arc::clone(s)),
            SliceTier::Spilled(_) => None,
        }
    }

    /// Bytes this cell currently keeps in RAM (0 while spilled).
    pub fn resident_bytes(&self) -> usize {
        if self.is_resident() {
            self.bytes
        } else {
            0
        }
    }

    /// Is the cell serving from RAM right now?
    pub fn is_resident(&self) -> bool {
        matches!(&*read_ignore_poison(&self.tier), SliceTier::Resident(_))
    }

    /// Record `n` lookups against this cell (the spill policy's heat).
    pub fn touch(&self, n: u64) {
        lock_ignore_poison(&self.heat).observe(n);
    }

    /// Current heat estimate (decayed history + untied touches).
    pub fn heat_score(&self) -> u64 {
        lock_ignore_poison(&self.heat).score()
    }

    fn spill_handle(&self) -> Option<SpillHandle> {
        match &*read_ignore_poison(&self.tier) {
            SliceTier::Resident(_) => None,
            SliceTier::Spilled(h) => Some(h.clone()),
        }
    }
}

impl Drop for SliceCell {
    fn drop(&mut self) {
        // Write-once files belong to exactly this cell (globally unique
        // names), so the last placement snapshot dropping the cell may
        // delete its spill file — retired replicas clean up after
        // themselves.
        if self.file_len.load(Ordering::Relaxed) > 0 {
            let _ = fs::remove_file(&self.spill_path);
        }
    }
}

/// Cumulative tier-transition counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Spilled slices loaded back into RAM.
    pub promotions: u64,
    /// Resident slices demoted to the disk tier.
    pub demotions: u64,
    /// Bytes read from spill files by promotions (prefetched reads
    /// included — a read is a read, whoever issued it).
    pub spill_read_bytes: u64,
    /// Bytes written to spill files by first-time demotions (header
    /// included).
    pub spill_write_bytes: u64,
    /// Payload bytes streamed chunk-by-chunk through first-time
    /// demotions' [`HashingWriter`] (i.e. `spill_write_bytes` minus the
    /// fixed headers) — the bytes that never existed as an in-RAM
    /// serialization buffer.
    pub demote_stream_bytes: u64,
    /// Async reads completed ahead of demand (segment prefetches and
    /// the `prefetch_window` warmer) whose payload was staged.
    pub prefetches: u64,
    /// Startup-sweep adoptions: orphaned spill files whose payload was
    /// byte-identical to an admitted cell's serialization and were
    /// renamed onto that cell's path (its first demotion skips the
    /// write).
    pub orphans_adopted: u64,
    /// Startup-sweep deletions: leftover `*.tmp` files and stray or
    /// corrupt `*.spill` files matching no admitted cell.
    pub orphans_deleted: u64,
    /// Corrupt/unwritable spill files encountered (the slice keeps its
    /// current tier; serving continues from the resident tier).
    pub spill_errors: u64,
}

/// Per-shard transition counters (lock-free; merged into `ShardStats`
/// snapshots by the engine).
#[derive(Default)]
struct ShardCounters {
    promotions: AtomicU64,
    demotions: AtomicU64,
    spill_read_bytes: AtomicU64,
    spill_errors: AtomicU64,
    prefetches: AtomicU64,
    orphans_adopted: AtomicU64,
}

/// A per-shard snapshot of the store's transition counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardSpill {
    /// Promotions of this shard's slices.
    pub promotions: u64,
    /// Demotions of this shard's slices.
    pub demotions: u64,
    /// Bytes promotions read back for this shard.
    pub spill_read_bytes: u64,
    /// Spill-file errors hit on this shard's slices.
    pub spill_errors: u64,
    /// Prefetched reads staged for this shard's slices.
    pub prefetches: u64,
    /// Orphaned files the startup sweep adopted for this shard's slices.
    pub orphans_adopted: u64,
}

/// One queued unit of background spill I/O.
enum IoJob {
    /// Serialize (first time) and flip one cell to the disk tier.
    Demote(Arc<SliceCell>),
    /// Read one spilled cell's file and stage the parsed slice.
    Prefetch(Arc<SliceCell>),
    /// Fault injection: occupy one worker doing nothing for the given
    /// time, simulating a wedged disk. Only the chaos harness pushes
    /// these — they let scenarios prove that serving degrades to inline
    /// reads (and recovers) when the async pool stops making progress.
    Stall(Duration),
}

/// The background pool's work queue. Lock order: the registry mutex may
/// be held while pushing here; I/O threads never touch the registry
/// while holding this lock (they pop, release, then run).
struct IoQueue {
    state: Mutex<IoQueueState>,
    cv: Condvar,
}

struct IoQueueState {
    jobs: VecDeque<IoJob>,
    shutdown: bool,
}

impl IoQueue {
    /// Background demote write: joins the back of the queue.
    fn push_back(&self, job: IoJob) {
        lock_ignore_poison(&self.state).jobs.push_back(job);
        self.cv.notify_one();
    }

    /// Request-path prefetch read: jumps ahead of queued demote writes.
    /// A serving thread may be parked on this very job (its promote
    /// lost the claim race to the prefetch), and a read is bounded and
    /// small next to a streamed multi-MB write — without the priority,
    /// one request could wait out the entire background write backlog.
    fn push_front(&self, job: IoJob) {
        lock_ignore_poison(&self.state).jobs.push_front(job);
        self.cv.notify_one();
    }
}

/// The engine's tiered-storage manager: owns the spill directory, the
/// resident-byte budget, the registry of every admitted cell, and the
/// background spill I/O pool.
pub struct SliceStore {
    inner: Arc<StoreInner>,
    io_threads: Vec<JoinHandle<()>>,
}

struct StoreInner {
    dir: PathBuf,
    budget: usize,
    /// Registry of admitted cells (weak: retired replicas drop out on
    /// their own). The mutex doubles as the tier-transition lock — it
    /// serializes victim selection, claim flips, and tier-pointer swaps;
    /// it is NEVER held across a spill-file read or write, and resident
    /// reads never take it.
    cells: Mutex<Vec<Weak<SliceCell>>>,
    per_shard: Vec<ShardCounters>,
    spill_write_bytes: AtomicU64,
    demote_stream_bytes: AtomicU64,
    orphans_deleted: AtomicU64,
    /// Demotions claimed but not yet completed (queued + writing).
    in_flight_demotes: AtomicUsize,
    /// Completion signaling for claim flips: demote/promote claim
    /// holders notify here when they finish, and budget waiters /
    /// promote latecomers wait here. The signal's mutex guards nothing
    /// but the wait itself (predicates read the per-cell claim flags).
    transitions: TransitionSignal,
    /// Background I/O queue; `None` runs spill I/O inline (still
    /// streaming, still off the registry lock).
    io: Option<IoQueue>,
    prefetch_window: usize,
    /// When the heat last decayed (rebalancer tick or the promotion-path
    /// fallback cadence).
    last_tick: Mutex<Instant>,
    /// Promotion-path decay cadence. `None` when a rebalancer drives
    /// [`SliceStore::tick`] — the spill heat must cool on *its* cadence,
    /// not faster, or replicas of a table the rebalancer still ranks hot
    /// would cool ahead of the table score that justified them.
    fallback_tick: Option<Duration>,
    /// When an external [`SliceStore::tick`] (manual `rebalance_once`
    /// passes included) last drove the decay. While one arrived within
    /// [`EXTERNAL_CLOCK_LEASE`], the promotion-path fallback stands down
    /// so heat never double-decays; once external ticks stop, the lease
    /// expires and the fallback resumes.
    last_external_tick: Mutex<Option<Instant>>,
    /// Remove the directory itself on drop (per-run default dirs only).
    cleanup_dir: bool,
}

impl SliceStore {
    /// Open (creating if needed) a store over `cfg.dir` for `num_shards`
    /// shards, and start its background I/O pool (`cfg.io_threads`
    /// threads; 0 = inline I/O). `rebalancer_ticks` says a rebalancer
    /// will drive [`SliceStore::tick`]; without one, promotions tick the
    /// heat themselves at most once per [`HEAT_TICK_INTERVAL`].
    pub fn new(
        cfg: &SpillConfig,
        num_shards: usize,
        rebalancer_ticks: bool,
    ) -> io::Result<SliceStore> {
        fs::create_dir_all(&cfg.dir)?;
        let inner = Arc::new(StoreInner {
            dir: cfg.dir.clone(),
            budget: cfg.resident_budget,
            cells: Mutex::new(Vec::new()),
            per_shard: (0..num_shards).map(|_| ShardCounters::default()).collect(),
            spill_write_bytes: AtomicU64::new(0),
            demote_stream_bytes: AtomicU64::new(0),
            orphans_deleted: AtomicU64::new(0),
            in_flight_demotes: AtomicUsize::new(0),
            transitions: TransitionSignal::new(),
            io: (cfg.io_threads > 0).then(|| IoQueue {
                state: Mutex::new(IoQueueState { jobs: VecDeque::new(), shutdown: false }),
                cv: Condvar::new(),
            }),
            prefetch_window: cfg.prefetch_window,
            last_tick: Mutex::new(Instant::now()),
            fallback_tick: (!rebalancer_ticks).then_some(HEAT_TICK_INTERVAL),
            last_external_tick: Mutex::new(None),
            cleanup_dir: cfg.cleanup_dir,
        });
        let io_threads = if inner.io.is_some() {
            (0..cfg.io_threads)
                .map(|i| {
                    let inner = Arc::clone(&inner);
                    std::thread::Builder::new()
                        .name(format!("emberq-spill-io-{i}"))
                        .spawn(move || io_loop(&inner))
                        .expect("spawn spill I/O worker")
                })
                .collect()
        } else {
            Vec::new()
        };
        Ok(SliceStore { inner, io_threads })
    }

    /// The resident-bytes budget.
    pub fn budget(&self) -> usize {
        self.inner.budget
    }

    /// Admit a freshly carved (or duplicated) slice: resident, tracked,
    /// with a globally unique spill path reserved for its first
    /// demotion.
    pub fn admit(&self, shard: usize, table: usize, slice: TableSlice) -> Arc<SliceCell> {
        self.inner.admit(shard, table, slice)
    }

    /// Bytes currently resident across every tracked cell (including
    /// cells only reachable from older placement snapshots — memory is
    /// memory, so the budget counts them too).
    pub fn resident_bytes(&self) -> usize {
        self.inner.resident_bytes()
    }

    /// Load `cell` back into the RAM tier and return its slice,
    /// demoting the coldest resident cells if the budget overflows.
    /// The fast path (already resident) takes no store lock; per-cell
    /// claim flags make the spill file read-once under contention; the
    /// spill file is read and written **outside** every store lock; and
    /// the caller waits (on a condvar, never the registry lock) for
    /// exactly the demotions its install commissioned, so residency is
    /// back under budget on return. A corrupt or truncated spill file
    /// is a clean error: the cell stays spilled, `spill_errors` counts
    /// it, and everything resident keeps serving.
    pub fn promote(&self, cell: &Arc<SliceCell>) -> io::Result<Arc<TableSlice>> {
        self.inner.promote(cell)
    }

    /// Demote coldest-first until residency fits the budget; returns
    /// once the commissioned writes completed. Called after startup
    /// carving and after rebalance passes (which admit new replicas
    /// resident).
    pub fn enforce(&self) {
        self.inner.enforce()
    }

    /// Demote every resident cell (tests and "drop caches" operations);
    /// returns how many were demoted. Runs inline (synchronous
    /// semantics), stops at the first write failure — which is counted
    /// in `spill_errors` like every other unwritable spill file.
    pub fn demote_all(&self) -> io::Result<usize> {
        self.inner.demote_all()
    }

    /// Advance every cell's decay window one tick — rebalance passes
    /// (background thread or manual `rebalance_once`) call this on their
    /// cadence, so spill heat and replication heat cool at the same
    /// rate. Also drops stale staged prefetches and, with a
    /// [`SpillConfig::prefetch_window`], warms the hottest spilled
    /// cells. Each call renews the [`EXTERNAL_CLOCK_LEASE`] standing the
    /// promotion-path fallback down.
    pub fn tick(&self) {
        self.inner.tick()
    }

    /// Issue overlapping async reads for the given spilled cells; each
    /// completed read stages its parsed slice on the cell for the next
    /// promotion to consume. Returns how many reads were issued (0
    /// without an I/O pool, or when every cell was already resident,
    /// staged, or claimed).
    pub fn prefetch<'a, I>(&self, cells: I) -> usize
    where
        I: IntoIterator<Item = &'a Arc<SliceCell>>,
    {
        self.inner.prefetch(cells)
    }

    /// Reconcile the spill directory against the admitted registry:
    /// delete `*.tmp` leftovers, adopt strays whose payload is
    /// byte-identical to an admitted cell's serialization, delete the
    /// rest (our naming scheme and other pids only). Call after
    /// admitting every cell and before the first enforcement, so
    /// adopted cells demote without rewriting.
    pub fn sweep_orphans(&self) {
        self.inner.sweep_orphans()
    }

    /// Retire a cell that a live-update snapshot swap replaced: drop it
    /// from the eviction registry (so it can never be chosen as a demote
    /// victim or counted against warming again) and, when it is resident
    /// with an already written spill file and no demotion in flight,
    /// unlink that file eagerly — its bytes describe the *old* table
    /// version, and nothing will ever read them again (promotions only
    /// happen from the spilled tier). A cell that is currently *spilled*
    /// keeps its file: in-flight batches on older placement snapshots
    /// may still promote it, and [`SliceCell`]'s drop deletes the file
    /// the moment the last snapshot lets go. Either way the stale bytes
    /// can never be re-adopted after a crash — the orphan sweep adopts
    /// on content digest, and the replacement cell's content differs.
    pub fn invalidate(&self, cell: &Arc<SliceCell>) {
        self.inner.invalidate(cell)
    }

    /// Fault injection for the chaos harness: occupy up to `threads`
    /// background I/O workers with jobs that do nothing but sleep for
    /// `d` (jumping the queue, like a stuck disk would stall whatever
    /// came first). Returns how many stall jobs were queued — 0 without
    /// an async pool. Serving must keep working while the pool is
    /// wedged: promotions fall back to inline reads on the serving
    /// thread by design.
    pub fn wedge_io(&self, d: Duration, threads: usize) -> usize {
        let Some(q) = &self.inner.io else { return 0 };
        let n = threads.min(self.io_threads.len());
        for _ in 0..n {
            q.push_front(IoJob::Stall(d));
        }
        n
    }

    /// Demotions claimed but not yet completed (queued or mid-write).
    /// Observability for tests and operators; racy by nature.
    pub fn demotions_in_flight(&self) -> usize {
        self.inner.in_flight_demotes.load(Ordering::Acquire)
    }

    /// Cumulative transition counters, totaled across shards.
    pub fn stats(&self) -> StoreStats {
        self.inner.stats()
    }

    /// One shard's transition counters (merged into `ShardStats`).
    pub fn shard_spill(&self, shard: usize) -> ShardSpill {
        self.inner.shard_spill(shard)
    }
}

impl Drop for SliceStore {
    fn drop(&mut self) {
        if let Some(q) = &self.inner.io {
            lock_ignore_poison(&q.state).shutdown = true;
            q.cv.notify_all();
        }
        for t in self.io_threads.drain(..) {
            let _ = t.join();
        }
        // Abandon whatever was still queued: dropping the jobs drops
        // their cell Arcs now, so every spill file is deleted before
        // StoreInner's drop tries to remove the (per-run default)
        // directory.
        if let Some(q) = &self.inner.io {
            lock_ignore_poison(&q.state).jobs.clear();
        }
    }
}

impl Drop for StoreInner {
    fn drop(&mut self) {
        // Only per-run default directories are removed (and only once
        // every cell — so every spill file — is gone; a shared directory
        // with other live stores survives). An operator-supplied
        // --spill-dir belongs to the operator and stays in place.
        if self.cleanup_dir {
            let _ = fs::remove_dir(&self.dir);
        }
    }
}

/// Background spill I/O worker: pop, release the queue lock, run.
fn io_loop(inner: &StoreInner) {
    let q = inner.io.as_ref().expect("I/O threads imply a queue");
    loop {
        let job = {
            let mut st = lock_ignore_poison(&q.state);
            loop {
                if let Some(job) = st.jobs.pop_front() {
                    break Some(job);
                }
                if st.shutdown {
                    break None;
                }
                st = q.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
            }
        };
        match job {
            Some(IoJob::Demote(cell)) => inner.run_demote(&cell),
            Some(IoJob::Prefetch(cell)) => inner.run_prefetch(&cell),
            Some(IoJob::Stall(d)) => std::thread::sleep(d),
            None => return,
        }
    }
}

impl StoreInner {
    fn admit(&self, shard: usize, table: usize, slice: TableSlice) -> Arc<SliceCell> {
        let name = format!(
            "slice-{:x}-{}.spill",
            process_token(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let cell = Arc::new(SliceCell::new(shard, table, slice, self.dir.join(name), false));
        lock_ignore_poison(&self.cells).push(Arc::downgrade(&cell));
        cell
    }

    fn resident_bytes(&self) -> usize {
        lock_ignore_poison(&self.cells)
            .iter()
            .filter_map(Weak::upgrade)
            .map(|c| c.resident_bytes())
            .sum()
    }

    fn invalidate(&self, cell: &Arc<SliceCell>) {
        let target = Arc::downgrade(cell);
        let demote_in_flight = {
            // Deregister under the lock: demote claims are only ever
            // minted from the registry (plan_evictions / demote_all)
            // while it is held, so after this block no *new* demotion
            // can touch the cell — only a claim that already existed.
            let mut reg = lock_ignore_poison(&self.cells);
            reg.retain(|w| w.strong_count() > 0 && !w.ptr_eq(&target));
            cell.demote_claim.is_claimed()
        };
        if demote_in_flight {
            // A demotion is mid-write (or about to flip the tier to the
            // file): leave the file alone; the cell's drop deletes it
            // once the last old placement snapshot releases the cell.
            return;
        }
        if cell.is_resident() && cell.file_len.swap(0, Ordering::AcqRel) > 0 {
            // Resident with a stale write-once file: nothing can read it
            // (promotions only start from the spilled tier), so reclaim
            // the disk bytes now instead of at the cell's drop.
            let _ = fs::remove_file(&cell.spill_path);
        }
    }

    /// Load `cell` back into the RAM tier and return its slice. The fast
    /// path (already resident) takes no store lock. The claim flag makes
    /// this read-once under contention: the claiming thread consumes a
    /// staged prefetch if one is parked on the cell, reads the spill
    /// file itself otherwise — **outside** every store lock — then takes
    /// the registry mutex only for the install + victim selection, and
    /// finally waits (lock-free) for the demotions it commissioned, so
    /// residency is back under budget when it returns. Latecomers for
    /// the same cell park on the transition condvar instead of
    /// duplicating the read. A corrupt or truncated spill file is a
    /// clean error: the cell stays spilled, `spill_errors` counts it,
    /// and everything resident keeps serving.
    fn promote(&self, cell: &Arc<SliceCell>) -> io::Result<Arc<TableSlice>> {
        loop {
            if let Some(s) = cell.resident() {
                return Ok(s);
            }
            if !cell.promote_claim.claim() {
                // Someone else (a worker or a prefetch job) owns this
                // cell's read; wait for their claim to clear, then
                // re-evaluate from the top.
                self.transitions.wait_until(|| {
                    !cell.promote_claim.is_claimed() || cell.resident().is_some()
                });
                continue;
            }
            // We own the claim. The previous owner may have installed
            // before our CAS — re-check.
            if let Some(s) = cell.resident() {
                self.finish_promote(cell);
                return Ok(s);
            }
            let staged = lock_ignore_poison(&cell.staged).take();
            let loaded = match staged {
                // A prefetch already paid the read (and counted its
                // bytes); we only install.
                Some(s) => s,
                None => {
                    let Some(handle) = cell.spill_handle() else {
                        // Unreachable in practice (not resident implies
                        // spilled), but a lost claim must never wedge.
                        self.finish_promote(cell);
                        continue;
                    };
                    match read_spill(&handle, cell) {
                        Ok(slice) => {
                            self.per_shard[cell.shard]
                                .spill_read_bytes
                                .fetch_add(handle.file_len, Ordering::Relaxed);
                            Arc::new(slice)
                        }
                        Err(e) => {
                            self.per_shard[cell.shard]
                                .spill_errors
                                .fetch_add(1, Ordering::Relaxed);
                            self.finish_promote(cell);
                            return Err(e);
                        }
                    }
                }
            };
            // Install + eviction planning under the registry lock; the
            // writes themselves happen after it is released.
            let (wait_set, jobs) = {
                let mut reg = lock_ignore_poison(&self.cells);
                self.maybe_tick_locked(&mut reg);
                *write_ignore_poison(&cell.tier) = SliceTier::Resident(Arc::clone(&loaded));
                self.per_shard[cell.shard].promotions.fetch_add(1, Ordering::Relaxed);
                self.plan_evictions(&mut reg, Some(cell))
            };
            self.finish_promote(cell);
            self.dispatch_demotes(jobs);
            self.wait_demotes(&wait_set);
            return Ok(loaded);
        }
    }

    fn finish_promote(&self, cell: &SliceCell) {
        cell.promote_claim.release();
        self.transitions.notify();
    }

    fn enforce(&self) {
        let (wait_set, jobs) = {
            let mut reg = lock_ignore_poison(&self.cells);
            self.plan_evictions(&mut reg, None)
        };
        self.dispatch_demotes(jobs);
        self.wait_demotes(&wait_set);
    }

    fn demote_all(&self) -> io::Result<usize> {
        // Claim every resident cell; cells another thread is already
        // demoting are waited out at the end instead.
        let (claimed, preexisting) = {
            let mut reg = lock_ignore_poison(&self.cells);
            reg.retain(|w| w.strong_count() > 0);
            let mut claimed: Vec<Arc<SliceCell>> = Vec::new();
            let mut preexisting: Vec<Arc<SliceCell>> = Vec::new();
            for cell in reg.iter().filter_map(Weak::upgrade) {
                if !cell.is_resident() {
                    continue;
                }
                if self.claim_demote(&cell) {
                    claimed.push(cell);
                } else {
                    preexisting.push(cell);
                }
            }
            (claimed, preexisting)
        };
        let mut demoted = 0usize;
        let mut failure: Option<io::Error> = None;
        for cell in &claimed {
            if failure.is_none() {
                match self.demote_cell(cell) {
                    Ok(0) => {}
                    Ok(_) => demoted += 1,
                    Err(e) => {
                        self.per_shard[cell.shard]
                            .spill_errors
                            .fetch_add(1, Ordering::Relaxed);
                        failure = Some(e);
                    }
                }
            }
            // Unprocessed tail after a failure just releases its claim
            // (matching the old stop-at-first-error semantics).
            self.finish_demote(cell);
        }
        self.wait_demotes(&preexisting);
        match failure {
            Some(e) => Err(e),
            None => Ok(demoted),
        }
    }

    fn tick(&self) {
        *lock_ignore_poison(&self.last_external_tick) = Some(Instant::now());
        let mut reg = lock_ignore_poison(&self.cells);
        self.tick_locked(&mut reg, 1);
    }

    fn tick_locked(&self, reg: &mut Vec<Weak<SliceCell>>, ticks: u32) {
        *lock_ignore_poison(&self.last_tick) = Instant::now();
        reg.retain(|w| w.strong_count() > 0);
        let cells: Vec<Arc<SliceCell>> = reg.iter().filter_map(Weak::upgrade).collect();
        for cell in &cells {
            {
                let mut heat = lock_ignore_poison(&cell.heat);
                for _ in 0..ticks {
                    heat.tick();
                }
            }
            // A staged prefetch nobody consumed within a whole tick is
            // stale: drop it, so warming a cell whose burst never came
            // cannot park its bytes outside the budgeted tier forever.
            // (Claimed cells are left alone — their prefetch is mid
            // flight and will stage a fresh copy.)
            if !cell.promote_claim.is_claimed() {
                lock_ignore_poison(&cell.staged).take();
            }
        }
        self.warm_locked(&cells);
    }

    /// The `prefetch_window` warmer: stage the N hottest spilled cells
    /// (rebalancer heat, hottest first) so a bursty table's first miss
    /// finds its payload already parsed.
    fn warm_locked(&self, cells: &[Arc<SliceCell>]) {
        if self.prefetch_window == 0 || self.io.is_none() {
            return;
        }
        let spilled: Vec<&Arc<SliceCell>> =
            cells.iter().filter(|c| !c.is_resident()).collect();
        let scores: Vec<u64> = spilled.iter().map(|c| c.heat_score()).collect();
        for i in hottest_indices(&scores, self.prefetch_window) {
            self.issue_prefetch(spilled[i]);
        }
    }

    fn prefetch<'a, I>(&self, cells: I) -> usize
    where
        I: IntoIterator<Item = &'a Arc<SliceCell>>,
    {
        let mut issued = 0usize;
        for cell in cells {
            if self.issue_prefetch(cell) {
                issued += 1;
            }
        }
        issued
    }

    fn issue_prefetch(&self, cell: &Arc<SliceCell>) -> bool {
        let Some(q) = &self.io else { return false };
        if cell.pinned.is_some() || cell.is_resident() {
            return false;
        }
        if lock_ignore_poison(&cell.staged).is_some() {
            return false; // already staged, nothing to read
        }
        if !cell.promote_claim.claim() {
            return false; // someone is already reading this cell
        }
        q.push_front(IoJob::Prefetch(Arc::clone(cell)));
        true
    }

    /// Prefetch job body (claim already held): read, stage, release.
    fn run_prefetch(&self, cell: &Arc<SliceCell>) {
        if cell.resident().is_none() {
            if let Some(handle) = cell.spill_handle() {
                match read_spill(&handle, cell) {
                    Ok(slice) => {
                        *lock_ignore_poison(&cell.staged) = Some(Arc::new(slice));
                        self.per_shard[cell.shard]
                            .spill_read_bytes
                            .fetch_add(handle.file_len, Ordering::Relaxed);
                        self.per_shard[cell.shard].prefetches.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        // Nothing staged; the consuming promote re-reads
                        // inline and counts the failure there — counting
                        // here too would report two errors per failed
                        // access. A warm-only failure on a never-touched
                        // cell stays uncounted until something actually
                        // needs the file.
                    }
                }
            }
        }
        self.finish_promote(cell);
    }

    /// The promotion-path decay fallback: without a rebalancer driving
    /// [`SliceStore::tick`], heat would otherwise accumulate forever and
    /// eviction would degrade to all-time LFU — dead-but-once-hot slices
    /// squatting the budget while the live working set churns. Inactive
    /// (`fallback_tick: None`) when a rebalancer owns the cadence, or
    /// while an external tick arrived within its lease. Applies one
    /// half-life per elapsed interval (capped), so heat decays by wall
    /// clock — an hour-long idle gap costs an hour of halvings, not one.
    fn maybe_tick_locked(&self, reg: &mut Vec<Weak<SliceCell>>) {
        let Some(interval) = self.fallback_tick else { return };
        let external = lock_ignore_poison(&self.last_external_tick)
            .is_some_and(|t| t.elapsed() < EXTERNAL_CLOCK_LEASE);
        if external {
            return; // an external clock is driving the decay right now
        }
        let elapsed = lock_ignore_poison(&self.last_tick).elapsed();
        let due = (elapsed.as_nanos() / interval.as_nanos().max(1))
            .min(MAX_CATCHUP_TICKS as u128) as u32;
        if due > 0 {
            self.tick_locked(reg, due);
        }
    }

    fn stats(&self) -> StoreStats {
        let mut s = StoreStats {
            spill_write_bytes: self.spill_write_bytes.load(Ordering::Relaxed),
            demote_stream_bytes: self.demote_stream_bytes.load(Ordering::Relaxed),
            orphans_deleted: self.orphans_deleted.load(Ordering::Relaxed),
            ..StoreStats::default()
        };
        for c in &self.per_shard {
            s.promotions += c.promotions.load(Ordering::Relaxed);
            s.demotions += c.demotions.load(Ordering::Relaxed);
            s.spill_read_bytes += c.spill_read_bytes.load(Ordering::Relaxed);
            s.spill_errors += c.spill_errors.load(Ordering::Relaxed);
            s.prefetches += c.prefetches.load(Ordering::Relaxed);
            s.orphans_adopted += c.orphans_adopted.load(Ordering::Relaxed);
        }
        s
    }

    fn shard_spill(&self, shard: usize) -> ShardSpill {
        let c = &self.per_shard[shard];
        ShardSpill {
            promotions: c.promotions.load(Ordering::Relaxed),
            demotions: c.demotions.load(Ordering::Relaxed),
            spill_read_bytes: c.spill_read_bytes.load(Ordering::Relaxed),
            spill_errors: c.spill_errors.load(Ordering::Relaxed),
            prefetches: c.prefetches.load(Ordering::Relaxed),
            orphans_adopted: c.orphans_adopted.load(Ordering::Relaxed),
        }
    }

    /// Eviction planning under the registry lock: pick coldest-first
    /// victims until residency (minus what in-flight demotions will
    /// free) fits the budget, claim them, and return `(wait_set, jobs)`
    /// — the cells whose completion the caller must wait out before its
    /// budget guarantee holds, and the newly claimed victims to hand to
    /// [`StoreInner::dispatch_demotes`] after the lock is released. No
    /// I/O happens here. `keep` (the just-promoted cell) is evicted only
    /// as a last resort, so a promotion can never be undone by its own
    /// enforcement unless the budget cannot hold even one slice.
    fn plan_evictions(
        &self,
        reg: &mut Vec<Weak<SliceCell>>,
        keep: Option<&Arc<SliceCell>>,
    ) -> (Vec<Arc<SliceCell>>, Vec<Arc<SliceCell>>) {
        reg.retain(|w| w.strong_count() > 0);
        let live: Vec<Arc<SliceCell>> = reg.iter().filter_map(Weak::upgrade).collect();
        let mut wait_set: Vec<Arc<SliceCell>> = Vec::new();
        let mut resident = 0usize;
        let mut in_flight = 0usize;
        for c in &live {
            let rb = c.resident_bytes();
            resident += rb;
            if rb > 0 && c.demote_claim.is_claimed() {
                in_flight += c.bytes;
                wait_set.push(Arc::clone(c));
            }
        }
        if resident <= self.budget {
            // Under budget right now: nothing to do, nothing to wait on.
            return (Vec::new(), Vec::new());
        }
        let mut jobs: Vec<Arc<SliceCell>> = Vec::new();
        if resident - in_flight > self.budget {
            let mut victims: Vec<&Arc<SliceCell>> = live
                .iter()
                .filter(|c| c.is_resident() && !c.demote_claim.is_claimed())
                .collect();
            // Coldest first, deterministic tie-break; the protected cell
            // sorts last. Keys are cached: concurrent touches must not
            // feed the sort an inconsistent ordering.
            victims.sort_by_cached_key(|c| {
                let protected = keep.is_some_and(|k| Arc::ptr_eq(k, *c));
                (protected, c.heat_score(), c.shard, c.table, c.global_lo)
            });
            let mut effective = resident - in_flight;
            for v in victims {
                if effective <= self.budget {
                    break;
                }
                if self.claim_demote(v) {
                    effective -= v.bytes;
                    jobs.push(Arc::clone(v));
                    wait_set.push(Arc::clone(v));
                }
            }
        }
        (wait_set, jobs)
    }

    fn claim_demote(&self, cell: &Arc<SliceCell>) -> bool {
        if cell.demote_claim.claim() {
            self.in_flight_demotes.fetch_add(1, Ordering::AcqRel);
            true
        } else {
            false
        }
    }

    fn finish_demote(&self, cell: &SliceCell) {
        cell.demote_claim.release();
        self.in_flight_demotes.fetch_sub(1, Ordering::AcqRel);
        self.transitions.notify();
    }

    /// Hand claimed victims to the I/O pool, or run them inline (still
    /// off the registry lock) when no pool exists.
    fn dispatch_demotes(&self, jobs: Vec<Arc<SliceCell>>) {
        match &self.io {
            Some(q) => {
                for cell in jobs {
                    q.push_back(IoJob::Demote(cell));
                }
            }
            None => {
                for cell in &jobs {
                    self.run_demote(cell);
                }
            }
        }
    }

    /// Demote job body (claim already held): write (first time), flip,
    /// release. Errors are counted; the cell then stays resident — over
    /// budget beats serving nothing.
    fn run_demote(&self, cell: &Arc<SliceCell>) {
        if self.demote_cell(cell).is_err() {
            self.per_shard[cell.shard].spill_errors.fetch_add(1, Ordering::Relaxed);
        }
        self.finish_demote(cell);
    }

    /// Block until every listed cell's demotion claim has cleared
    /// (written and flipped, or failed). Lock-free with respect to the
    /// registry: only the transition condvar's mutex is held, and only
    /// across the predicate check.
    fn wait_demotes(&self, cells: &[Arc<SliceCell>]) {
        if cells.is_empty() {
            return;
        }
        self.transitions
            .wait_until(|| !cells.iter().any(|c| c.demote_claim.is_claimed()));
    }

    /// Move one cell to the disk tier (streaming its spill file the
    /// first time); returns the resident bytes freed (0 if it was not
    /// resident). Caller holds the cell's demote claim, NOT the registry
    /// lock: the whole serialization runs lock-free — lookups touching
    /// the victim keep serving the resident slice for the entire write,
    /// and promotions of other cells proceed in parallel. The registry
    /// mutex is taken only for the final tier-pointer flip.
    fn demote_cell(&self, cell: &Arc<SliceCell>) -> io::Result<usize> {
        let Some(slice) = cell.resident() else { return Ok(0) };
        let mut file_len = cell.file_len.load(Ordering::Relaxed);
        if file_len == 0 {
            let (total, payload) = write_spill(&cell.spill_path, &slice)?;
            file_len = total;
            cell.file_len.store(file_len, Ordering::Relaxed);
            self.spill_write_bytes.fetch_add(file_len, Ordering::Relaxed);
            self.demote_stream_bytes.fetch_add(payload, Ordering::Relaxed);
        }
        {
            let _reg = lock_ignore_poison(&self.cells);
            *write_ignore_poison(&cell.tier) = SliceTier::Spilled(SpillHandle {
                path: cell.spill_path.clone(),
                file_len,
            });
        }
        self.per_shard[cell.shard].demotions.fetch_add(1, Ordering::Relaxed);
        Ok(cell.bytes)
    }

    fn sweep_orphans(&self) {
        let me = process_token();
        let Ok(entries) = fs::read_dir(&self.dir) else { return };
        let cells: Vec<Arc<SliceCell>> = {
            let mut reg = lock_ignore_poison(&self.cells);
            reg.retain(|w| w.strong_count() > 0);
            reg.iter().filter_map(Weak::upgrade).collect()
        };
        // Lazy content fingerprints: serializing a slice through a
        // hash-only sink is CPU work, so each candidate pays it at most
        // once however many orphans probe it.
        let mut digests: Vec<Option<Option<(u64, u64)>>> = vec![None; cells.len()];
        let mut deleted = 0u64;
        for entry in entries.flatten() {
            let path = entry.path();
            let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
            // Only files of our own naming scheme are ours to judge; an
            // operator's directory may hold unrelated data.
            if !name.starts_with("slice-") {
                continue;
            }
            let is_tmp = name.ends_with(".spill.tmp");
            if !is_tmp && !name.ends_with(".spill") {
                continue;
            }
            // Files bearing this process's run token belong to live
            // sibling stores sharing the directory — never adopt or
            // delete them. A dead predecessor's files carry a different
            // token even when the OS recycled our pid.
            if spill_file_token(name) == Some(me) {
                continue;
            }
            if is_tmp {
                // A crashed demotion's half-written temp: always garbage
                // (a completed write renames away from .tmp atomically).
                if fs::remove_file(&path).is_ok() {
                    deleted += 1;
                }
                continue;
            }
            if self.try_adopt(&path, &cells, &mut digests) {
                continue;
            }
            if fs::remove_file(&path).is_ok() {
                deleted += 1;
            }
        }
        self.orphans_deleted.fetch_add(deleted, Ordering::Relaxed);
    }

    /// Adopt `path` into an admitted cell if its (validated) payload is
    /// byte-identical to what that cell's first demotion would write:
    /// rename it onto the cell's reserved path and mark the write-once
    /// step done. Returns whether the file was adopted.
    fn try_adopt(
        &self,
        path: &Path,
        cells: &[Arc<SliceCell>],
        digests: &mut [Option<Option<(u64, u64)>>],
    ) -> bool {
        let Ok(info) = read_orphan(path) else { return false };
        for (i, cell) in cells.iter().enumerate() {
            if cell.file_len.load(Ordering::Relaxed) != 0 {
                continue; // already has its own file
            }
            if info.lo != cell.global_lo || info.hi != cell.global_lo + cell.rows {
                continue;
            }
            if info.fmt_tag != cell.fmt_tag {
                continue; // same rows, different (or stale) format
            }
            let digest = digests[i].get_or_insert_with(|| cell_digest(cell));
            if *digest != Some((info.payload_len, info.checksum)) {
                continue;
            }
            if fs::rename(path, &cell.spill_path).is_err() {
                return false; // unusable in place; let the caller delete it
            }
            cell.file_len.store(info.file_len, Ordering::Relaxed);
            self.per_shard[cell.shard].orphans_adopted.fetch_add(1, Ordering::Relaxed);
            return true;
        }
        false
    }
}

/// `(payload_len, fnv1a64)` of a cell's serialization, computed through
/// a hash-only sink — no bytes are buffered or written anywhere.
fn cell_digest(cell: &SliceCell) -> Option<(u64, u64)> {
    let slice = cell.resident()?;
    let mut hw = HashingWriter::new(io::sink());
    serial::write_any(&mut hw, slice.table()).ok()?;
    Some(hw.digest())
}

/// The run-token component of a `slice-<token:hex>-<seq>.spill[.tmp]`
/// file name.
fn spill_file_token(name: &str) -> Option<u64> {
    let rest = name.strip_prefix("slice-")?;
    let (token, _) = rest.split_once('-')?;
    u64::from_str_radix(token, 16).ok()
}

/// A validated orphan spill file's identity.
struct OrphanInfo {
    lo: usize,
    hi: usize,
    fmt_tag: u16,
    payload_len: u64,
    checksum: u64,
    file_len: u64,
}

/// Parse and fully validate an orphan candidate: header fields, payload
/// length, and checksum (the payload is hash-streamed, never buffered).
fn read_orphan(path: &Path) -> io::Result<OrphanInfo> {
    let mut f = File::open(path)?;
    let file_len = f.metadata()?.len();
    let mut header = [0u8; SPILL_HEADER_BYTES as usize];
    f.read_exact(&mut header)?;
    if &header[0..8] != SPILL_MAGIC {
        return Err(bad("magic"));
    }
    let u64_at = |off: usize| {
        u64::from_le_bytes(header[off..off + 8].try_into().expect("fixed-width header"))
    };
    let lo = u64_at(8) as usize;
    let hi = u64_at(16) as usize;
    let fmt_tag = u16::from_le_bytes(header[24..26].try_into().expect("fixed-width header"));
    let payload_len = u64_at(26);
    let checksum = u64_at(34);
    if payload_len != file_len.saturating_sub(SPILL_HEADER_BYTES) {
        return Err(bad("payload length"));
    }
    let mut hw = HashingWriter::new(io::sink());
    io::copy(&mut f, &mut hw)?;
    if hw.digest() != (payload_len, checksum) {
        return Err(bad("checksum"));
    }
    Ok(OrphanInfo { lo, hi, fmt_tag, payload_len, checksum, file_len })
}

fn bad(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("corrupt spill file: {what}"))
}

/// Stream `slice` into the spill container at `path`, crash-safely:
/// the bytes go to `<path>.tmp` first (payload streamed chunk by chunk
/// through a [`HashingWriter`] — no full serialized payload in RAM),
/// the header's length/checksum are patched in place, and the temp is
/// atomically renamed onto `path`. Returns `(file_len, payload_len)`.
/// On any failure the temp is removed and `path` is untouched.
fn write_spill(path: &Path, slice: &TableSlice) -> io::Result<(u64, u64)> {
    let tmp = PathBuf::from(format!("{}.tmp", path.display()));
    let result = write_spill_tmp(&tmp, slice).and_then(|lens| {
        fs::rename(&tmp, path)?;
        Ok(lens)
    });
    if result.is_err() {
        let _ = fs::remove_file(&tmp);
    }
    result
}

fn write_spill_tmp(tmp: &Path, slice: &TableSlice) -> io::Result<(u64, u64)> {
    let range = slice.global_rows();
    let mut w = BufWriter::new(File::create(tmp)?);
    w.write_all(SPILL_MAGIC)?;
    w.write_all(&(range.start as u64).to_le_bytes())?;
    w.write_all(&(range.end as u64).to_le_bytes())?;
    w.write_all(&serial::format_tag(slice.table()).to_le_bytes())?;
    // Placeholder for [payload_len][checksum], patched after streaming.
    w.write_all(&[0u8; 16])?;
    let mut hw = HashingWriter::new(w);
    serial::write_any(&mut hw, slice.table())?;
    let (payload_len, checksum) = hw.digest();
    let mut f = hw.into_inner().into_inner().map_err(|e| e.into_error())?;
    f.seek(SeekFrom::Start(SPILL_LEN_OFFSET))?;
    f.write_all(&payload_len.to_le_bytes())?;
    f.write_all(&checksum.to_le_bytes())?;
    Ok((SPILL_HEADER_BYTES + payload_len, payload_len))
}

/// Load and validate a spill file against the cell that owns it. Every
/// failure mode — wrong magic, truncation, length mismatch, checksum
/// mismatch, shape mismatch — is a clean `InvalidData`/`UnexpectedEof`
/// error, never a panic.
fn read_spill(handle: &SpillHandle, cell: &SliceCell) -> io::Result<TableSlice> {
    let mut f = File::open(&handle.path)?;
    let actual_len = f.metadata()?.len();
    if actual_len != handle.file_len {
        return Err(bad("file length changed since demotion"));
    }
    let mut header = [0u8; SPILL_HEADER_BYTES as usize];
    f.read_exact(&mut header)?;
    if &header[0..8] != SPILL_MAGIC {
        return Err(bad("magic"));
    }
    let u64_at = |off: usize| {
        u64::from_le_bytes(header[off..off + 8].try_into().expect("fixed-width header"))
    };
    let lo = u64_at(8) as usize;
    let hi = u64_at(16) as usize;
    let fmt_tag = u16::from_le_bytes(header[24..26].try_into().expect("fixed-width header"));
    let payload_len = u64_at(26);
    let checksum = u64_at(34);
    if lo != cell.global_lo || hi != cell.global_lo + cell.rows {
        return Err(bad("global row range"));
    }
    if fmt_tag != cell.fmt_tag {
        return Err(bad("format tag"));
    }
    if payload_len != actual_len - SPILL_HEADER_BYTES {
        return Err(bad("payload length"));
    }
    let mut payload = vec![0u8; payload_len as usize];
    f.read_exact(&mut payload)?;
    if serial::fnv1a64(&payload) != checksum {
        return Err(bad("checksum"));
    }
    let table = serial::read_any(&mut payload.as_slice())?;
    if table.rows() != cell.rows || table.dim() != cell.dim {
        return Err(bad("payload shape"));
    }
    if serial::format_tag(&table) != fmt_tag {
        return Err(bad("format tag"));
    }
    Ok(TableSlice::from_parts(table, lo..hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GreedyQuantizer;
    use crate::table::serial::AnyTable;
    use crate::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};

    fn cfg_for(dir: PathBuf, budget: usize) -> SpillConfig {
        SpillConfig {
            dir,
            resident_budget: budget,
            cleanup_dir: true,
            io_threads: 2,
            prefetch_window: 0,
        }
    }

    fn tmp_store(name: &str, budget: usize) -> SliceStore {
        let dir = std::env::temp_dir()
            .join(format!("emberq_store_test_{name}_{}", std::process::id()));
        SliceStore::new(&cfg_for(dir, budget), 4, false).unwrap()
    }

    fn any_table(fmt: usize, rows: usize, dim: usize, seed: u64) -> AnyTable {
        let t = EmbeddingTable::randn(rows, dim, seed);
        match fmt {
            0 => AnyTable::F32(t),
            1 => AnyTable::Fused(t.quantize_fused(
                &GreedyQuantizer::default(),
                4,
                ScaleBiasDtype::F16,
            )),
            2 => AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32),
            ),
            _ => AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::TwoTier { k: 3 }, ScaleBiasDtype::F16),
            ),
        }
    }

    /// Spin (bounded) until `cond` holds — the async pool's completions
    /// are signaled, not synchronous, so tests poll with a watchdog.
    fn wait_for(what: &str, mut cond: impl FnMut() -> bool) {
        let t0 = Instant::now();
        while !cond() {
            assert!(t0.elapsed() < Duration::from_secs(20), "timed out waiting for {what}");
            std::thread::yield_now();
        }
    }

    #[test]
    fn demote_promote_round_trip_every_format() {
        let store = tmp_store("round_trip", usize::MAX);
        for fmt in 0..4usize {
            let table = any_table(fmt, 24, 8, 0x70 + fmt as u64);
            let slice = TableSlice::cut(&table, 4..20);
            let mut want = vec![0.0f32; 8];
            slice.pool(&[0, 15, 7, 7], &mut want);
            let cell = store.admit(fmt % 4, fmt, slice);
            assert!(cell.is_resident());
            assert_eq!(store.demote_all().unwrap(), 1, "fmt {fmt}");
            assert!(!cell.is_resident());
            assert!(cell.spill_handle().unwrap().path().exists());
            let back = store.promote(&cell).unwrap();
            assert!(cell.is_resident());
            assert_eq!(back.rows(), 16);
            assert_eq!(back.global_rows(), 4..20);
            let mut got = vec![0.0f32; 8];
            back.pool(&[0, 15, 7, 7], &mut got);
            assert_eq!(got, want, "fmt {fmt}: reload must be bit-exact");
            // Drop the cell before the next format so the write-once
            // file is cleaned up.
            let path = cell.spill_handle().map(|h| h.path().to_path_buf());
            drop(back);
            drop(cell);
            if let Some(p) = path {
                assert!(!p.exists(), "fmt {fmt}: dropped cell must delete its file");
            }
        }
        let s = store.stats();
        assert_eq!(s.promotions, 4);
        assert_eq!(s.demotions, 4);
        assert!(s.spill_read_bytes > 0 && s.spill_write_bytes > 0);
        assert_eq!(s.spill_errors, 0);
    }

    #[test]
    fn streaming_demote_is_crash_safe_and_counted() {
        let store = tmp_store("streaming", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 40, 16, 0x71), 0..40);
        let cell = store.admit(0, 0, slice);
        assert_eq!(store.demote_all().unwrap(), 1);
        // The temp never survives a completed write; the final file does.
        let path = cell.spill_handle().unwrap().path().to_path_buf();
        assert!(path.exists());
        assert!(
            !PathBuf::from(format!("{}.tmp", path.display())).exists(),
            "completed demote must leave no .tmp behind"
        );
        // Streamed-payload accounting: file bytes = header + payload.
        let s = store.stats();
        assert!(s.demote_stream_bytes > 0);
        assert_eq!(s.spill_write_bytes, s.demote_stream_bytes + SPILL_HEADER_BYTES);
        assert_eq!(s.spill_write_bytes, fs::metadata(&path).unwrap().len());
        // And the streamed header round-trips through the validating
        // reader (length + checksum were patched correctly).
        assert!(store.promote(&cell).is_ok());
    }

    #[test]
    fn second_demotion_reuses_the_file() {
        let store = tmp_store("write_once", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 16, 8, 0x80), 0..16);
        let cell = store.admit(0, 0, slice);
        assert_eq!(store.demote_all().unwrap(), 1);
        let written = store.stats().spill_write_bytes;
        assert!(written > 0);
        store.promote(&cell).unwrap();
        assert_eq!(store.demote_all().unwrap(), 1);
        assert_eq!(store.stats().spill_write_bytes, written, "write-once");
        assert_eq!(store.stats().demotions, 2);
    }

    #[test]
    fn budget_evicts_the_coldest_cell() {
        // Three equal slices, budget for two: after touching two of them
        // and enforcing, the untouched one must be the spilled one.
        let slice = |seed| TableSlice::cut(&any_table(0, 32, 8, seed), 0..32);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("coldest", 2 * bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        let c = store.admit(2, 2, slice(3));
        a.touch(100);
        c.touch(50);
        store.enforce();
        assert!(a.is_resident());
        assert!(!b.is_resident(), "the cold cell spills");
        assert!(c.is_resident());
        assert!(store.resident_bytes() <= 2 * bytes);
        // Touch b hard and promote: now the coldest of the others goes.
        b.touch(500);
        store.promote(&b).unwrap();
        assert!(b.is_resident());
        assert!(!c.is_resident(), "c (heat 50) is colder than a (heat 100)");
        assert!(store.resident_bytes() <= store.budget());
    }

    #[test]
    fn decay_tick_cools_spill_heat() {
        let slice = |seed| TableSlice::cut(&any_table(0, 16, 4, seed), 0..16);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("decay", bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        a.touch(1000); // old burst
        for _ in 0..12 {
            store.tick(); // 1000 decays to 0
        }
        b.touch(10); // fresh trickle beats fully decayed burst
        store.enforce();
        assert!(!a.is_resident());
        assert!(b.is_resident());
    }

    #[test]
    fn truncated_and_corrupt_files_are_clean_errors() {
        let store = tmp_store("corrupt", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 20, 16, 0x90), 0..20);
        let cell = store.admit(0, 0, slice);
        store.demote_all().unwrap();
        let path = cell.spill_handle().unwrap().path().to_path_buf();
        let good = fs::read(&path).unwrap();

        // Truncation.
        fs::write(&path, &good[..good.len() - 7]).unwrap();
        assert!(store.promote(&cell).is_err());
        assert!(!cell.is_resident());

        // Payload bit flip (length intact, checksum must catch it).
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        fs::write(&path, &flipped).unwrap();
        let err = store.promote(&cell).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum"), "{err}");

        // Wrong magic.
        let mut wrong = good.clone();
        wrong[0] = b'X';
        fs::write(&path, &wrong).unwrap();
        assert!(store.promote(&cell).is_err());

        // Missing file entirely.
        fs::remove_file(&path).unwrap();
        assert!(store.promote(&cell).is_err());
        assert_eq!(store.stats().spill_errors, 4);
        assert_eq!(store.stats().promotions, 0);

        // Restore the original bytes: the cell recovers fully.
        fs::write(&path, &good).unwrap();
        assert!(store.promote(&cell).is_ok());
        assert!(cell.is_resident());
    }

    #[test]
    fn format_tag_mismatch_is_a_clean_error() {
        // The header's fmt_tag (offset 24, outside the payload checksum)
        // must match the owning cell: a file holding the right rows in
        // the wrong format — e.g. left behind by an interrupted online
        // re-quantization — is rejected, not served.
        let store = tmp_store("fmt_tag", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 20, 16, 0x91), 0..20);
        let expect = serial::format_tag(slice.table());
        let cell = store.admit(0, 0, slice);
        assert_eq!(cell.fmt_tag(), expect);
        store.demote_all().unwrap();
        let path = cell.spill_handle().unwrap().path().to_path_buf();
        let good = fs::read(&path).unwrap();
        let mut tagged = good.clone();
        tagged[24] ^= 0xFF; // corrupt the tag, leave payload + checksum intact
        fs::write(&path, &tagged).unwrap();
        let err = store.promote(&cell).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("format tag"), "{err}");
        fs::write(&path, &good).unwrap();
        assert!(store.promote(&cell).is_ok());
    }

    #[test]
    fn untracked_cells_never_spill_and_are_pinned() {
        let slice = TableSlice::cut(&any_table(0, 8, 4, 0xA0), 0..8);
        let cell = SliceCell::untracked(0, 0, slice);
        assert!(cell.is_resident());
        assert_eq!(cell.resident_bytes(), cell.bytes());
        assert_eq!(cell.rows(), 8);
        assert_eq!(cell.dim(), 4);
        // The untiered fast path: a plain borrow, no tier lock.
        let pinned = cell.pinned().expect("untracked cells pin their slice");
        assert_eq!(pinned.rows(), 8);
        // Store-tracked cells are not pinned (their tier can change).
        let store = tmp_store("pinned", usize::MAX);
        let tracked = store.admit(0, 0, TableSlice::cut(&any_table(0, 8, 4, 0xA1), 0..8));
        assert!(tracked.pinned().is_none());
    }

    #[test]
    fn promotion_fallback_tick_decays_without_a_rebalancer() {
        // Heat decays on the promotion path itself once the fallback
        // interval elapses — the budget-without-rebalancer configuration
        // must not degrade to all-time LFU.
        let slice = |seed| TableSlice::cut(&any_table(0, 16, 4, seed), 0..16);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("fallback_tick", bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        a.touch(1_000_000); // historically hot, then dead
        store.enforce(); // b spills (a is hotter)
        assert!(a.is_resident() && !b.is_resident());
        // Rewind the clock instead of sleeping: make the fallback
        // cadence consider a tick due, enough times that a's ancient
        // heat fully decays below fresh traffic.
        for _ in 0..25 {
            *lock_ignore_poison(&store.inner.last_tick) = Instant::now() - HEAT_TICK_INTERVAL;
            let mut reg = lock_ignore_poison(&store.inner.cells);
            store.inner.maybe_tick_locked(&mut reg);
        }
        b.touch(10);
        store.promote(&b).unwrap();
        assert!(b.is_resident(), "fresh traffic wins");
        assert!(!a.is_resident(), "fully decayed history loses the budget");
    }

    #[test]
    fn external_ticks_lease_the_fallback_down_but_not_forever() {
        // Manual rebalance_once passes (no configured interval) also
        // drive store.tick(); while they keep arriving, the
        // promotion-path fallback must stand down or heat would decay on
        // two clocks. But the stand-down is a *lease*: once external
        // ticks stop for EXTERNAL_CLOCK_LEASE, the fallback resumes — a
        // one-off rebalance poke on a budget-only engine must not freeze
        // the heat clock for the rest of the process.
        let store = tmp_store("lease", usize::MAX); // fallback armed
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 8, 4, 0xB1), 0..8));
        a.touch(64);
        store.tick(); // an external clock takes over
        assert_eq!(a.heat_score(), 64);
        *lock_ignore_poison(&store.inner.last_tick) = Instant::now() - HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.inner.cells);
            store.inner.maybe_tick_locked(&mut reg);
        }
        assert_eq!(a.heat_score(), 64, "no fallback decay inside the lease");
        // The external clock goes silent past its lease: the next
        // promotion-path check decays again.
        *lock_ignore_poison(&store.inner.last_external_tick) =
            Some(Instant::now() - EXTERNAL_CLOCK_LEASE);
        *lock_ignore_poison(&store.inner.last_tick) = Instant::now() - HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.inner.cells);
            store.inner.maybe_tick_locked(&mut reg);
        }
        assert_eq!(a.heat_score(), 32, "expired lease hands the clock back");
    }

    #[test]
    fn fallback_catches_up_one_halving_per_elapsed_interval() {
        // Heat decays by wall clock, not by promotion count: a long idle
        // gap applies every missed half-life at once, so a dead-but-
        // once-hot slice cannot outrank live traffic for dozens of
        // subsequent evictions.
        let store = tmp_store("catchup", usize::MAX);
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 8, 4, 0xB2), 0..8));
        a.touch(1 << 20);
        *lock_ignore_poison(&store.inner.last_tick) =
            Instant::now() - 10 * HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.inner.cells);
            store.inner.maybe_tick_locked(&mut reg);
        }
        // The first catch-up tick folds the fresh burst (no halving),
        // the other nine halve it: 2^20 >> 9.
        assert_eq!(a.heat_score(), 1 << 11, "10 elapsed intervals, one catch-up pass");
        // And an absurd gap is capped at 64 ticks (enough to zero this
        // heat) instead of looping a million times.
        *lock_ignore_poison(&store.inner.last_tick) =
            Instant::now() - 1_000_000 * HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.inner.cells);
            store.inner.maybe_tick_locked(&mut reg);
        }
        assert_eq!(a.heat_score(), 0, "capped catch-up still decays stale heat to zero");
    }

    #[test]
    fn fallback_tick_is_inert_when_a_rebalancer_owns_the_cadence() {
        // With rebalancer_ticks the spill heat must cool on the
        // rebalancer's clock only, or replicas of a still-hot table
        // would cool ahead of the table score that justified them.
        let dir = std::env::temp_dir()
            .join(format!("emberq_store_test_inert_{}", std::process::id()));
        let store = SliceStore::new(&cfg_for(dir, usize::MAX), 4, true).unwrap();
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 16, 4, 0xB0), 0..16));
        a.touch(100);
        *lock_ignore_poison(&store.inner.last_tick) =
            Instant::now() - 10 * HEAT_TICK_INTERVAL;
        let mut reg = lock_ignore_poison(&store.inner.cells);
        store.inner.maybe_tick_locked(&mut reg);
        drop(reg);
        assert_eq!(a.heat_score(), 100, "no promotion-path decay");
        store.tick(); // the rebalancer's tick folds and decays as usual
        assert_eq!(a.heat_score(), 100);
        store.tick();
        assert_eq!(a.heat_score(), 50);
    }

    #[test]
    fn promotion_protects_the_touched_cell() {
        // Budget of one slice: promoting a spilled cell must evict the
        // other resident cell, not immediately re-evict itself.
        let slice = |seed| TableSlice::cut(&any_table(0, 16, 8, seed), 0..16);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("protect", bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        a.touch(10);
        store.enforce();
        assert!(a.is_resident() && !b.is_resident());
        store.promote(&b).unwrap();
        assert!(b.is_resident(), "the freshly promoted cell stays");
        assert!(!a.is_resident(), "the other one pays");
        assert!(store.resident_bytes() <= bytes);
    }

    #[test]
    fn registry_lock_is_free_during_demote_serialization() {
        // The tentpole contract: the registry mutex is held only for the
        // cell-state flips, never across a victim's serialization. A big
        // FP32 slice makes the first-time write take real wall time; a
        // concurrent thread must be able to take the registry lock (and
        // promote a different cell) while that write is in flight.
        let store = tmp_store("off_lock", usize::MAX);
        // Small cell, spilled up front (tiny file).
        let small = TableSlice::cut(&any_table(1, 16, 8, 0xC0), 0..16);
        let mut want = vec![0.0f32; 8];
        small.pool(&[0, 15], &mut want);
        let b = store.admit(1, 1, small);
        assert_eq!(store.demote_all().unwrap(), 1);
        // Big cell: ~16 MB of f32, serialized 4 bytes at a time — its
        // first demotion takes milliseconds, not microseconds.
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 16_384, 256, 0xC1), 0..16_384));
        std::thread::scope(|scope| {
            let t = scope.spawn(|| store.demote_all().unwrap());
            wait_for("the big demote to start", || store.demotions_in_flight() > 0);
            // While the victim is serializing, the registry lock must be
            // takeable (the I/O thread only grabs it for the final flip).
            let mut proven = false;
            while store.demotions_in_flight() > 0 {
                if let Ok(guard) = store.inner.cells.try_lock() {
                    let still_writing = store.demotions_in_flight() > 0;
                    drop(guard);
                    if still_writing {
                        proven = true;
                        break;
                    }
                }
                std::thread::yield_now();
            }
            assert!(proven, "registry lock was held for the whole serialization");
            // And a promotion of a *different* cell completes while the
            // victim is still being written.
            let back = store.promote(&b).unwrap();
            let mut got = vec![0.0f32; 8];
            back.pool(&[0, 15], &mut got);
            assert_eq!(got, want, "concurrent promote must serve bit-exactly");
            assert_eq!(t.join().unwrap(), 1, "demote_all demoted exactly the big cell");
        });
        assert!(!a.is_resident());
        assert!(b.is_resident());
        assert_eq!(store.stats().spill_errors, 0);
    }

    #[test]
    fn orphan_sweep_adopts_valid_files_and_deletes_strays() {
        let dir = std::env::temp_dir()
            .join(format!("emberq_store_test_sweep_{}", std::process::id()));
        let _ = fs::remove_dir_all(&dir);
        let mk_slice = || TableSlice::cut(&any_table(1, 30, 8, 0xD0), 2..28);
        let mut want = vec![0.0f32; 8];
        mk_slice().pool(&[0, 25, 13], &mut want);
        // A previous "run" writes a spill file whose bytes we keep as an
        // orphan (crafted under run token 0, which a live store can
        // never hold — the sweep must never touch files bearing the
        // live process's token, which belong to sibling stores). The
        // unrelated table's file plays the stale stray.
        {
            let mut cfg = cfg_for(dir.clone(), usize::MAX);
            cfg.cleanup_dir = false;
            let prev = SliceStore::new(&cfg, 4, false).unwrap();
            let cell = prev.admit(0, 0, mk_slice());
            let other =
                prev.admit(1, 1, TableSlice::cut(&any_table(1, 30, 8, 0xD1), 2..28));
            prev.demote_all().unwrap();
            fs::copy(&cell.spill_path, dir.join("slice-0-100.spill")).unwrap();
            // Same shape + range, different content: must NOT be adopted.
            fs::copy(&other.spill_path, dir.join("slice-0-101.spill")).unwrap();
        } // prev drops: its own files deleted, our copies survive
        fs::write(dir.join("slice-0-102.spill.tmp"), b"half-written junk").unwrap();
        fs::write(dir.join("slice-0-103.spill"), b"not a spill file at all").unwrap();
        fs::write(dir.join("keep.txt"), b"operator data, not ours").unwrap();

        let mut cfg = cfg_for(dir.clone(), usize::MAX);
        cfg.cleanup_dir = false;
        let store = SliceStore::new(&cfg, 4, false).unwrap();
        let cell = store.admit(2, 0, mk_slice());
        store.sweep_orphans();
        let s = store.stats();
        assert_eq!(s.orphans_adopted, 1, "the byte-identical orphan is adopted");
        assert_eq!(
            s.orphans_deleted, 3,
            "tmp + garbage + wrong-content strays are deleted"
        );
        assert!(dir.join("keep.txt").exists(), "foreign files are never touched");
        assert!(!dir.join("slice-0-101.spill").exists());
        assert!(!dir.join("slice-0-102.spill.tmp").exists());
        assert!(!dir.join("slice-0-103.spill").exists());
        // Adoption attribution lands on the owning cell's shard.
        assert_eq!(store.shard_spill(2).orphans_adopted, 1);
        // The payoff: the adopted file satisfies the write-once step, so
        // the first demotion flips without writing a byte...
        assert!(cell.file_len.load(Ordering::Relaxed) > 0);
        assert_eq!(store.demote_all().unwrap(), 1);
        assert_eq!(store.stats().spill_write_bytes, 0, "no rewrite after adoption");
        // ...and the re-adopted file serves bit-exactly.
        let back = store.promote(&cell).unwrap();
        let mut got = vec![0.0f32; 8];
        back.pool(&[0, 25, 13], &mut got);
        assert_eq!(got, want, "adopted spill file must serve bit-exactly");
        assert_eq!(store.stats().spill_errors, 0);
        drop(back);
        drop(cell);
        drop(store);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn prefetch_stages_the_read_and_promote_consumes_it() {
        let store = tmp_store("prefetch", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 24, 8, 0xE0), 0..24);
        let mut want = vec![0.0f32; 8];
        slice.pool(&[3, 23], &mut want);
        let a = store.admit(0, 0, slice);
        store.demote_all().unwrap();
        let file_len = a.spill_handle().unwrap().file_len();
        assert_eq!(store.prefetch([&a]), 1, "one async read issued");
        wait_for("the prefetch to stage", || store.stats().prefetches == 1);
        assert_eq!(store.stats().spill_read_bytes, file_len);
        assert!(!a.is_resident(), "staging must not install (budget accounting)");
        // The promotion consumes the staged copy: no second read.
        let back = store.promote(&a).unwrap();
        assert!(a.is_resident());
        assert_eq!(store.stats().spill_read_bytes, file_len, "read exactly once");
        assert_eq!(store.stats().promotions, 1);
        let mut got = vec![0.0f32; 8];
        back.pool(&[3, 23], &mut got);
        assert_eq!(got, want);
        // Prefetching a resident cell is a no-op.
        assert_eq!(store.prefetch([&a]), 0);
    }

    #[test]
    fn warm_window_stages_the_hottest_spilled_cell_and_ticks_drop_stale_stages() {
        let dir = std::env::temp_dir()
            .join(format!("emberq_store_test_warm_{}", std::process::id()));
        let mut cfg = cfg_for(dir, usize::MAX);
        cfg.prefetch_window = 1;
        let store = SliceStore::new(&cfg, 4, false).unwrap();
        let slice = |seed| TableSlice::cut(&any_table(1, 24, 8, seed), 0..24);
        let a = store.admit(0, 0, slice(0xE1));
        let b = store.admit(1, 1, slice(0xE2));
        store.demote_all().unwrap();
        b.touch(50);
        a.touch(5);
        store.tick(); // warms exactly the hottest spilled cell: b
        wait_for("the warmer to stage b", || store.stats().prefetches == 1);
        let b_len = b.spill_handle().unwrap().file_len();
        assert_eq!(store.stats().spill_read_bytes, b_len, "only b was read");
        store.promote(&b).unwrap();
        assert_eq!(store.stats().spill_read_bytes, b_len, "warm read was consumed");
        // A staged copy nobody consumes is dropped on the next tick: the
        // eventual promote pays a fresh read.
        assert_eq!(store.prefetch([&a]), 1);
        wait_for("the prefetch to stage a", || store.stats().prefetches == 2);
        let read_after_stage = store.stats().spill_read_bytes;
        store.tick(); // drops a's stale staged slice (b is resident now)
        store.promote(&a).unwrap();
        assert!(
            store.stats().spill_read_bytes > read_after_stage,
            "stale staged copy was dropped, so the promote re-read the file"
        );
    }

    #[test]
    fn invalidate_unlinks_resident_stale_files_and_defers_spilled_ones() {
        let store = tmp_store("invalidate", usize::MAX);
        let slice = |seed| TableSlice::cut(&any_table(1, 20, 8, seed), 0..20);
        // Resident cell with a written file: invalidation reclaims the
        // stale bytes immediately.
        let a = store.admit(0, 0, slice(0xF0));
        store.demote_all().unwrap();
        store.promote(&a).unwrap();
        let a_path = a.spill_path.clone();
        assert!(a_path.exists());
        store.invalidate(&a);
        assert!(!a_path.exists(), "resident cell's stale file is unlinked eagerly");
        assert_eq!(a.file_len.load(Ordering::Relaxed), 0);
        // Spilled cell: the file must survive invalidation (an in-flight
        // batch on the old snapshot may still promote it) and serve
        // bit-exactly, then disappear with the last reference.
        let b = store.admit(1, 1, slice(0xF1));
        let mut want = vec![0.0f32; 8];
        slice(0xF1).pool(&[2, 19], &mut want);
        store.demote_all().unwrap();
        let b_path = b.spill_path.clone();
        store.invalidate(&b);
        assert!(b_path.exists(), "spilled cell keeps its file for old-snapshot readers");
        let back = store.promote(&b).unwrap();
        let mut got = vec![0.0f32; 8];
        back.pool(&[2, 19], &mut got);
        assert_eq!(got, want, "old version stays promotable until released");
        drop(back);
        drop(b);
        assert!(!b_path.exists(), "drop of the last reference deletes the file");
        // Invalidated cells are out of the registry: an enforce pass
        // must not pick them as victims (budget 0 would demote anything
        // it can see).
        let store2 = tmp_store("invalidate2", 0);
        let c = store2.admit(0, 0, slice(0xF2));
        store2.invalidate(&c);
        store2.enforce();
        assert!(c.is_resident(), "deregistered cells are never demoted");
    }

    #[test]
    fn wedged_io_pool_degrades_to_inline_reads_and_recovers() {
        let store = tmp_store("wedge", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 24, 8, 0xF5), 0..24);
        let mut want = vec![0.0f32; 8];
        slice.pool(&[1, 23], &mut want);
        let cell = store.admit(0, 0, slice);
        store.demote_all().unwrap();
        // Wedge both workers, then promote: the read must complete
        // inline on this thread, well before the stalls expire.
        assert_eq!(store.wedge_io(Duration::from_millis(300), 2), 2);
        let t0 = Instant::now();
        let back = store.promote(&cell).unwrap();
        assert!(
            t0.elapsed() < Duration::from_millis(250),
            "promotion must not wait out a wedged pool"
        );
        let mut got = vec![0.0f32; 8];
        back.pool(&[1, 23], &mut got);
        assert_eq!(got, want);
        // Recovery: once the stalls drain, queued work flows again.
        drop(back);
        store.demote_all().unwrap();
        assert_eq!(store.prefetch([&cell]), 1);
        wait_for("the pool to recover and stage the prefetch", || {
            store.stats().prefetches == 1
        });
    }
}
