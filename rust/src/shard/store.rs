//! Tiered slice storage: hot shard slices serve from RAM, cold ones
//! spill to disk and promote back on touch.
//!
//! The paper shrinks embedding tables to ~14% of FP32 so production
//! models fit in memory; this module takes the next capacity step — the
//! served model no longer has to fit even its *quantized* bytes in RAM.
//! Every placement entry is a [`SliceCell`] whose tier is either
//! [`SliceTier::Resident`] (an `Arc<TableSlice>` in the table's native
//! format) or [`SliceTier::Spilled`] (a [`SpillHandle`] naming an
//! on-disk file). The [`SliceStore`] owns the policy:
//!
//! * **Spill format** — `[8B "EMBQSPL1"][global_lo u64][global_hi u64]
//!   [payload_len u64][fnv1a64 u64][payload]` where the payload is the
//!   slice's table in the exact `table::serial` container (`EMBQTBL1`),
//!   so a spilled slice keeps its native quantized encoding (int4+tails,
//!   codebook, fused, fp32) byte for byte. Headers, lengths, checksum,
//!   and shape are all validated on load: a truncated or corrupted file
//!   is a clean `io::Error`, never a panic.
//! * **Write-once** — slices are immutable, so a slice is serialized at
//!   most once; later demotions just drop the resident `Arc` and flip
//!   the tier back to the existing file. A cell deletes its file on
//!   drop (e.g. when the rebalancer retires a replica).
//! * **Admission / eviction** — every slice is admitted resident
//!   (startup carve, promotion, new replicas). Whenever residency
//!   exceeds the byte budget, the store demotes the *coldest* resident
//!   cells — ranked by the same exponential-decay
//!   [`DecayWindow`](crate::shard::load::DecayWindow) heat the
//!   rebalancer ranks tables by, ticked on the same cadence — until the
//!   budget holds. The cell that triggered the promotion is evicted only
//!   as a last resort (it is by definition the hottest thing in the
//!   room), so the post-transition residency is always `<= budget`.
//! * **Concurrency** — tier transitions serialize on the store's
//!   registry mutex (promotion reads and demotion writes are cold-path
//!   disk I/O); the hot path only ever takes a cell's tier `RwLock` for
//!   the instant it takes to clone the resident `Arc`. In-flight
//!   executions hold their own `Arc<TableSlice>` clones, so demoting a
//!   slice mid-batch is safe — the memory is freed when the last
//!   execution finishes.

use std::fs::{self, File};
use std::io::{self, BufWriter, Read, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock, Weak};
use std::time::{Duration, Instant};

use crate::shard::load::DecayWindow;
use crate::shard::slice::TableSlice;
use crate::table::serial;
use crate::util::sync::{lock_ignore_poison, read_ignore_poison, write_ignore_poison};

const SPILL_MAGIC: &[u8; 8] = b"EMBQSPL1";
/// magic + global_lo + global_hi + payload_len + checksum.
const SPILL_HEADER_BYTES: u64 = 8 + 8 + 8 + 8 + 8;

/// Fallback decay cadence: when no rebalancer drives [`SliceStore::tick`]
/// (the `--resident-budget` without `--rebalance-interval` configuration),
/// promotions tick the heat themselves at most this often, so eviction
/// stays recency-weighted instead of silently degrading to all-time LFU.
const HEAT_TICK_INTERVAL: Duration = Duration::from_secs(1);

/// How long an external [`SliceStore::tick`] (a rebalance pass) keeps the
/// promotion-path fallback stood down. While external ticks keep
/// arriving inside this lease, the fallback never fires (one clock,
/// never two); once they stop for a whole lease — e.g. a one-off manual
/// `rebalance_once` poke on a budget-only engine — the fallback resumes,
/// so the heat clock can never be frozen permanently.
const EXTERNAL_CLOCK_LEASE: Duration = Duration::from_secs(5);

/// Catch-up cap for the fallback clock: after an idle gap it applies one
/// half-life per elapsed [`HEAT_TICK_INTERVAL`], at most this many (64
/// halvings zero any u64, so a longer cap would be pure waste).
const MAX_CATCHUP_TICKS: u32 = 64;

/// Globally unique spill-file suffix, so engines sharing a directory
/// (tests, multiple servers per process) can never collide or delete
/// each other's files.
static SPILL_SEQ: AtomicU64 = AtomicU64::new(0);

/// Tiered-storage configuration of one engine.
#[derive(Clone, Debug)]
pub struct SpillConfig {
    /// Directory the spill files live in (created on start).
    pub dir: PathBuf,
    /// Resident-bytes budget across all slices. `usize::MAX` admits
    /// everything and only spills on explicit demotion.
    pub resident_budget: usize,
    /// Remove `dir` itself on shutdown. Set for the per-run default
    /// temp directory; an operator-supplied `--spill-dir` is left in
    /// place (only the spill files inside it are deleted).
    pub cleanup_dir: bool,
}

/// Where a spilled slice's bytes live on disk.
#[derive(Clone, Debug)]
pub struct SpillHandle {
    path: PathBuf,
    /// Total file bytes (header + payload) — the cost of a promotion.
    file_len: u64,
}

impl SpillHandle {
    /// The spill file's path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total file bytes (what a promotion reads back).
    pub fn file_len(&self) -> u64 {
        self.file_len
    }
}

/// Which tier a slice currently lives in.
pub enum SliceTier {
    /// In RAM, serving directly.
    Resident(Arc<TableSlice>),
    /// On disk; a touch promotes it back.
    Spilled(SpillHandle),
}

/// One placement entry: a slice's identity + metadata (always resident)
/// and its tier (RAM or disk). Cells are shared by `Arc` across
/// placement snapshots, so a promotion is visible to every snapshot at
/// once.
pub struct SliceCell {
    shard: usize,
    table: usize,
    rows: usize,
    dim: usize,
    global_lo: usize,
    /// Logical bytes when resident (the slice's native-format payload).
    bytes: usize,
    tier: RwLock<SliceTier>,
    /// Spill-file path (assigned at admission; empty for untracked
    /// cells, which never spill).
    spill_path: PathBuf,
    /// File bytes once written; 0 = never spilled (write-once marker).
    file_len: AtomicU64,
    /// Exponential-decay touch heat — same arithmetic as the
    /// rebalancer's per-table windows, ticked on the same cadence.
    heat: Mutex<DecayWindow>,
    /// Untracked cells pin their slice here (the tier can never change),
    /// giving the untiered engine a lock-free, clone-free resolution
    /// path identical in cost to the pre-tiering design. `None` for
    /// store-tracked cells.
    pinned: Option<Arc<TableSlice>>,
}

impl SliceCell {
    fn new(
        shard: usize,
        table: usize,
        slice: TableSlice,
        spill_path: PathBuf,
        pin: bool,
    ) -> SliceCell {
        let range = slice.global_rows();
        let rows = slice.rows();
        let dim = slice.dim();
        let bytes = slice.size_bytes();
        let slice = Arc::new(slice);
        SliceCell {
            shard,
            table,
            rows,
            dim,
            global_lo: range.start,
            bytes,
            tier: RwLock::new(SliceTier::Resident(Arc::clone(&slice))),
            spill_path,
            file_len: AtomicU64::new(0),
            heat: Mutex::new(DecayWindow::new()),
            pinned: pin.then_some(slice),
        }
    }

    /// A cell outside any store: always resident, never spills, and its
    /// slice is [`SliceCell::pinned`] for lock-free resolution. The
    /// engine uses these when tiered storage is not configured so the
    /// placement type stays uniform without taxing the hot path.
    pub fn untracked(shard: usize, table: usize, slice: TableSlice) -> SliceCell {
        SliceCell::new(shard, table, slice, PathBuf::new(), true)
    }

    /// The untracked fast path: a plain borrow of the pinned slice.
    /// `None` for store-tracked cells (their tier can change, so they
    /// must go through `resident()`/`promote()`).
    pub fn pinned(&self) -> Option<&TableSlice> {
        self.pinned.as_deref()
    }

    /// Owning shard.
    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Table this cell slices.
    pub fn table(&self) -> usize {
        self.table
    }

    /// Rows held (tier-independent metadata — valid while spilled, which
    /// is what lets routing validation run without touching disk).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Embedding dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Logical bytes when resident.
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// The resident slice, if this cell is in the RAM tier.
    pub fn resident(&self) -> Option<Arc<TableSlice>> {
        match &*read_ignore_poison(&self.tier) {
            SliceTier::Resident(s) => Some(Arc::clone(s)),
            SliceTier::Spilled(_) => None,
        }
    }

    /// Bytes this cell currently keeps in RAM (0 while spilled).
    pub fn resident_bytes(&self) -> usize {
        if self.is_resident() {
            self.bytes
        } else {
            0
        }
    }

    /// Is the cell serving from RAM right now?
    pub fn is_resident(&self) -> bool {
        matches!(&*read_ignore_poison(&self.tier), SliceTier::Resident(_))
    }

    /// Record `n` lookups against this cell (the spill policy's heat).
    pub fn touch(&self, n: u64) {
        lock_ignore_poison(&self.heat).observe(n);
    }

    /// Current heat estimate (decayed history + untied touches).
    pub fn heat_score(&self) -> u64 {
        lock_ignore_poison(&self.heat).score()
    }

    fn spill_handle(&self) -> Option<SpillHandle> {
        match &*read_ignore_poison(&self.tier) {
            SliceTier::Resident(_) => None,
            SliceTier::Spilled(h) => Some(h.clone()),
        }
    }
}

impl Drop for SliceCell {
    fn drop(&mut self) {
        // Write-once files belong to exactly this cell (globally unique
        // names), so the last placement snapshot dropping the cell may
        // delete its spill file — retired replicas clean up after
        // themselves.
        if self.file_len.load(Ordering::Relaxed) > 0 {
            let _ = fs::remove_file(&self.spill_path);
        }
    }
}

/// Cumulative tier-transition counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Spilled slices loaded back into RAM.
    pub promotions: u64,
    /// Resident slices demoted to the disk tier.
    pub demotions: u64,
    /// Bytes read from spill files by promotions.
    pub spill_read_bytes: u64,
    /// Bytes written to spill files by first-time demotions.
    pub spill_write_bytes: u64,
    /// Corrupt/unwritable spill files encountered (the slice keeps its
    /// current tier; serving continues from the resident tier).
    pub spill_errors: u64,
}

/// Per-shard transition counters (lock-free; merged into `ShardStats`
/// snapshots by the engine).
#[derive(Default)]
struct ShardCounters {
    promotions: AtomicU64,
    demotions: AtomicU64,
    spill_read_bytes: AtomicU64,
    spill_errors: AtomicU64,
}

/// A per-shard snapshot of the store's transition counters.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShardSpill {
    /// Promotions of this shard's slices.
    pub promotions: u64,
    /// Demotions of this shard's slices.
    pub demotions: u64,
    /// Bytes promotions read back for this shard.
    pub spill_read_bytes: u64,
    /// Spill-file errors hit on this shard's slices.
    pub spill_errors: u64,
}

/// The engine's tiered-storage manager: owns the spill directory, the
/// resident-byte budget, and the registry of every admitted cell.
pub struct SliceStore {
    dir: PathBuf,
    budget: usize,
    /// Registry of admitted cells (weak: retired replicas drop out on
    /// their own). The mutex doubles as the tier-transition lock —
    /// promote/demote/enforce serialize on it; resident reads never
    /// take it.
    cells: Mutex<Vec<Weak<SliceCell>>>,
    per_shard: Vec<ShardCounters>,
    spill_write_bytes: AtomicU64,
    /// When the heat last decayed (rebalancer tick or the promotion-path
    /// fallback cadence).
    last_tick: Mutex<Instant>,
    /// Promotion-path decay cadence. `None` when a rebalancer drives
    /// [`SliceStore::tick`] — the spill heat must cool on *its* cadence,
    /// not faster, or replicas of a table the rebalancer still ranks hot
    /// would cool ahead of the table score that justified them.
    fallback_tick: Option<Duration>,
    /// When an external [`SliceStore::tick`] (manual `rebalance_once`
    /// passes included) last drove the decay. While one arrived within
    /// [`EXTERNAL_CLOCK_LEASE`], the promotion-path fallback stands down
    /// so heat never double-decays; once external ticks stop, the lease
    /// expires and the fallback resumes.
    last_external_tick: Mutex<Option<Instant>>,
    /// Remove the directory itself on drop (per-run default dirs only).
    cleanup_dir: bool,
}

impl SliceStore {
    /// Open (creating if needed) a store over `cfg.dir` for `num_shards`
    /// shards. `rebalancer_ticks` says a rebalancer will drive
    /// [`SliceStore::tick`]; without one, promotions tick the heat
    /// themselves at most once per [`HEAT_TICK_INTERVAL`].
    pub fn new(
        cfg: &SpillConfig,
        num_shards: usize,
        rebalancer_ticks: bool,
    ) -> io::Result<SliceStore> {
        fs::create_dir_all(&cfg.dir)?;
        Ok(SliceStore {
            dir: cfg.dir.clone(),
            budget: cfg.resident_budget,
            cells: Mutex::new(Vec::new()),
            per_shard: (0..num_shards).map(|_| ShardCounters::default()).collect(),
            spill_write_bytes: AtomicU64::new(0),
            last_tick: Mutex::new(Instant::now()),
            fallback_tick: (!rebalancer_ticks).then_some(HEAT_TICK_INTERVAL),
            last_external_tick: Mutex::new(None),
            cleanup_dir: cfg.cleanup_dir,
        })
    }

    /// The resident-bytes budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Admit a freshly carved (or duplicated) slice: resident, tracked,
    /// with a globally unique spill path reserved for its first
    /// demotion.
    pub fn admit(&self, shard: usize, table: usize, slice: TableSlice) -> Arc<SliceCell> {
        let name = format!(
            "slice-{}-{}.spill",
            std::process::id(),
            SPILL_SEQ.fetch_add(1, Ordering::Relaxed)
        );
        let cell = Arc::new(SliceCell::new(shard, table, slice, self.dir.join(name), false));
        lock_ignore_poison(&self.cells).push(Arc::downgrade(&cell));
        cell
    }

    /// Bytes currently resident across every tracked cell (including
    /// cells only reachable from older placement snapshots — memory is
    /// memory, so the budget counts them too).
    pub fn resident_bytes(&self) -> usize {
        lock_ignore_poison(&self.cells)
            .iter()
            .filter_map(Weak::upgrade)
            .map(|c| c.resident_bytes())
            .sum()
    }

    /// Load `cell` back into the RAM tier and return its slice,
    /// demoting the coldest resident cells if the budget overflows. The
    /// fast path (already resident) takes no store lock, and the spill
    /// file is read **outside** the registry lock too, so promotions of
    /// different cells proceed in parallel (two threads racing on the
    /// *same* cell may duplicate the read; the loser discards its copy
    /// and only the installer counts). A corrupt or truncated spill
    /// file is a clean error: the cell stays spilled, `spill_errors`
    /// counts it, and everything resident keeps serving.
    pub fn promote(&self, cell: &Arc<SliceCell>) -> io::Result<Arc<TableSlice>> {
        loop {
            if let Some(s) = cell.resident() {
                return Ok(s);
            }
            // The tier can flip between the check above and here; retry
            // on the (rare) mid-transition read.
            let Some(handle) = cell.spill_handle() else { continue };
            let loaded = match read_spill(&handle, cell) {
                Ok(slice) => Arc::new(slice),
                Err(e) => {
                    self.per_shard[cell.shard].spill_errors.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
            };
            let mut reg = lock_ignore_poison(&self.cells);
            self.maybe_tick_locked(&mut reg);
            if let Some(s) = cell.resident() {
                return Ok(s); // lost the race: another thread installed first
            }
            *write_ignore_poison(&cell.tier) = SliceTier::Resident(Arc::clone(&loaded));
            self.per_shard[cell.shard].promotions.fetch_add(1, Ordering::Relaxed);
            self.per_shard[cell.shard]
                .spill_read_bytes
                .fetch_add(handle.file_len, Ordering::Relaxed);
            self.enforce_locked(&mut reg, Some(cell));
            return Ok(loaded);
        }
    }

    /// Demote coldest-first until residency fits the budget. Called
    /// after startup carving and after rebalance passes (which admit new
    /// replicas resident).
    pub fn enforce(&self) {
        let mut reg = lock_ignore_poison(&self.cells);
        self.enforce_locked(&mut reg, None);
    }

    /// Demote every resident cell (tests and "drop caches" operations);
    /// returns how many were demoted. Stops at the first write failure —
    /// which is counted in `spill_errors` like every other unwritable
    /// spill file, so the monitoring signal stays consistent with the
    /// enforcement path.
    pub fn demote_all(&self) -> io::Result<usize> {
        let mut reg = lock_ignore_poison(&self.cells);
        reg.retain(|w| w.strong_count() > 0);
        let live: Vec<Arc<SliceCell>> = reg.iter().filter_map(Weak::upgrade).collect();
        let mut demoted = 0usize;
        for cell in &live {
            match self.demote_cell(cell) {
                Ok(0) => {}
                Ok(_) => demoted += 1,
                Err(e) => {
                    self.per_shard[cell.shard].spill_errors.fetch_add(1, Ordering::Relaxed);
                    return Err(e);
                }
            }
        }
        Ok(demoted)
    }

    /// Advance every cell's decay window one tick — rebalance passes
    /// (background thread or manual `rebalance_once`) call this on their
    /// cadence, so spill heat and replication heat cool at the same
    /// rate. Each call renews the [`EXTERNAL_CLOCK_LEASE`] standing the
    /// promotion-path fallback down: one clock, never two — but a
    /// one-off poke cannot freeze the heat clock forever.
    pub fn tick(&self) {
        *lock_ignore_poison(&self.last_external_tick) = Some(Instant::now());
        let mut reg = lock_ignore_poison(&self.cells);
        self.tick_locked(&mut reg, 1);
    }

    fn tick_locked(&self, reg: &mut Vec<Weak<SliceCell>>, ticks: u32) {
        *lock_ignore_poison(&self.last_tick) = Instant::now();
        reg.retain(|w| w.strong_count() > 0);
        for cell in reg.iter().filter_map(Weak::upgrade) {
            let mut heat = lock_ignore_poison(&cell.heat);
            for _ in 0..ticks {
                heat.tick();
            }
        }
    }

    /// The promotion-path decay fallback: without a rebalancer driving
    /// [`SliceStore::tick`], heat would otherwise accumulate forever and
    /// eviction would degrade to all-time LFU — dead-but-once-hot slices
    /// squatting the budget while the live working set churns. Inactive
    /// (`fallback_tick: None`) when a rebalancer owns the cadence, or
    /// while an external tick arrived within its lease. Applies one
    /// half-life per elapsed interval (capped), so heat decays by wall
    /// clock — an hour-long idle gap costs an hour of halvings, not one.
    fn maybe_tick_locked(&self, reg: &mut Vec<Weak<SliceCell>>) {
        let Some(interval) = self.fallback_tick else { return };
        let external = lock_ignore_poison(&self.last_external_tick)
            .is_some_and(|t| t.elapsed() < EXTERNAL_CLOCK_LEASE);
        if external {
            return; // an external clock is driving the decay right now
        }
        let elapsed = lock_ignore_poison(&self.last_tick).elapsed();
        let due = (elapsed.as_nanos() / interval.as_nanos().max(1))
            .min(MAX_CATCHUP_TICKS as u128) as u32;
        if due > 0 {
            self.tick_locked(reg, due);
        }
    }

    /// Cumulative transition counters, totaled across shards.
    pub fn stats(&self) -> StoreStats {
        let mut s = StoreStats {
            spill_write_bytes: self.spill_write_bytes.load(Ordering::Relaxed),
            ..StoreStats::default()
        };
        for c in &self.per_shard {
            s.promotions += c.promotions.load(Ordering::Relaxed);
            s.demotions += c.demotions.load(Ordering::Relaxed);
            s.spill_read_bytes += c.spill_read_bytes.load(Ordering::Relaxed);
            s.spill_errors += c.spill_errors.load(Ordering::Relaxed);
        }
        s
    }

    /// One shard's transition counters (merged into `ShardStats`).
    pub fn shard_spill(&self, shard: usize) -> ShardSpill {
        let c = &self.per_shard[shard];
        ShardSpill {
            promotions: c.promotions.load(Ordering::Relaxed),
            demotions: c.demotions.load(Ordering::Relaxed),
            spill_read_bytes: c.spill_read_bytes.load(Ordering::Relaxed),
            spill_errors: c.spill_errors.load(Ordering::Relaxed),
        }
    }

    /// Eviction pass under the registry lock: demote coldest-first until
    /// `resident <= budget`. `keep` (the just-promoted cell) is evicted
    /// only as a last resort, so a promotion can never be undone by its
    /// own enforcement unless the budget cannot hold even one slice.
    fn enforce_locked(&self, reg: &mut Vec<Weak<SliceCell>>, keep: Option<&Arc<SliceCell>>) {
        reg.retain(|w| w.strong_count() > 0);
        let live: Vec<Arc<SliceCell>> = reg.iter().filter_map(Weak::upgrade).collect();
        let mut resident: usize = live.iter().map(|c| c.resident_bytes()).sum();
        if resident <= self.budget {
            return;
        }
        let mut victims: Vec<&Arc<SliceCell>> =
            live.iter().filter(|c| c.is_resident()).collect();
        // Coldest first, deterministic tie-break; the protected cell
        // sorts last. Keys are cached: concurrent touches must not feed
        // the sort an inconsistent ordering.
        victims.sort_by_cached_key(|c| {
            let protected = keep.is_some_and(|k| Arc::ptr_eq(k, *c));
            (protected, c.heat_score(), c.shard, c.table, c.global_lo)
        });
        for v in victims {
            if resident <= self.budget {
                break;
            }
            match self.demote_cell(v) {
                Ok(freed) => resident -= freed,
                Err(_) => {
                    // Unwritable spill file (disk full, bad dir): the
                    // slice stays resident — over budget beats serving
                    // nothing — and the error is counted.
                    self.per_shard[v.shard].spill_errors.fetch_add(1, Ordering::Relaxed);
                }
            }
        }
    }

    /// Move one cell to the disk tier (writing its spill file the first
    /// time); returns the resident bytes freed (0 if already spilled).
    /// Caller holds the registry lock — every tier *transition* does, so
    /// the tier cannot flip between the read below and the final swap —
    /// but the victim's tier lock is NOT held across the file write:
    /// lookups touching the victim keep serving the resident slice for
    /// the whole (one-time, write-once) serialization and only wait out
    /// the brief pointer swap at the end.
    fn demote_cell(&self, cell: &Arc<SliceCell>) -> io::Result<usize> {
        let Some(slice) = cell.resident() else { return Ok(0) };
        let mut file_len = cell.file_len.load(Ordering::Relaxed);
        if file_len == 0 {
            file_len = match write_spill(&cell.spill_path, &slice) {
                Ok(n) => n,
                Err(e) => {
                    // A half-written file must not linger: it would leak
                    // (Drop only deletes when file_len > 0) and block the
                    // spill directory's removal on shutdown.
                    let _ = fs::remove_file(&cell.spill_path);
                    return Err(e);
                }
            };
            cell.file_len.store(file_len, Ordering::Relaxed);
            self.spill_write_bytes.fetch_add(file_len, Ordering::Relaxed);
        }
        *write_ignore_poison(&cell.tier) = SliceTier::Spilled(SpillHandle {
            path: cell.spill_path.clone(),
            file_len,
        });
        self.per_shard[cell.shard].demotions.fetch_add(1, Ordering::Relaxed);
        Ok(cell.bytes)
    }
}

impl Drop for SliceStore {
    fn drop(&mut self) {
        // Only per-run default directories are removed (and only once
        // every cell — so every spill file — is gone; a shared directory
        // with other live stores survives). An operator-supplied
        // --spill-dir belongs to the operator and stays in place.
        if self.cleanup_dir {
            let _ = fs::remove_dir(&self.dir);
        }
    }
}

fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn bad(what: &str) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, format!("corrupt spill file: {what}"))
}

/// Serialize `slice` to `path` in the spill container; returns the file
/// length. The payload is the slice's table in its native `table::serial`
/// encoding, framed with the global row range and an FNV-1a checksum.
fn write_spill(path: &Path, slice: &TableSlice) -> io::Result<u64> {
    let mut payload = Vec::new();
    serial::write_any(&mut payload, slice.table())?;
    let range = slice.global_rows();
    let mut w = BufWriter::new(File::create(path)?);
    w.write_all(SPILL_MAGIC)?;
    w.write_all(&(range.start as u64).to_le_bytes())?;
    w.write_all(&(range.end as u64).to_le_bytes())?;
    w.write_all(&(payload.len() as u64).to_le_bytes())?;
    w.write_all(&fnv1a64(&payload).to_le_bytes())?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(SPILL_HEADER_BYTES + payload.len() as u64)
}

/// Load and validate a spill file against the cell that owns it. Every
/// failure mode — wrong magic, truncation, length mismatch, checksum
/// mismatch, shape mismatch — is a clean `InvalidData`/`UnexpectedEof`
/// error, never a panic.
fn read_spill(handle: &SpillHandle, cell: &SliceCell) -> io::Result<TableSlice> {
    let mut f = File::open(&handle.path)?;
    let actual_len = f.metadata()?.len();
    if actual_len != handle.file_len {
        return Err(bad("file length changed since demotion"));
    }
    let mut header = [0u8; SPILL_HEADER_BYTES as usize];
    f.read_exact(&mut header)?;
    if &header[0..8] != SPILL_MAGIC {
        return Err(bad("magic"));
    }
    let u64_at = |off: usize| {
        u64::from_le_bytes(header[off..off + 8].try_into().expect("fixed-width header"))
    };
    let lo = u64_at(8) as usize;
    let hi = u64_at(16) as usize;
    let payload_len = u64_at(24);
    let checksum = u64_at(32);
    if lo != cell.global_lo || hi != cell.global_lo + cell.rows {
        return Err(bad("global row range"));
    }
    if payload_len != actual_len - SPILL_HEADER_BYTES {
        return Err(bad("payload length"));
    }
    let mut payload = vec![0u8; payload_len as usize];
    f.read_exact(&mut payload)?;
    if fnv1a64(&payload) != checksum {
        return Err(bad("checksum"));
    }
    let table = serial::read_any(&mut payload.as_slice())?;
    if table.rows() != cell.rows || table.dim() != cell.dim {
        return Err(bad("payload shape"));
    }
    Ok(TableSlice::from_parts(table, lo..hi))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GreedyQuantizer;
    use crate::table::serial::AnyTable;
    use crate::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};

    fn tmp_store(name: &str, budget: usize) -> SliceStore {
        let dir = std::env::temp_dir()
            .join(format!("emberq_store_test_{name}_{}", std::process::id()));
        let cfg = SpillConfig { dir, resident_budget: budget, cleanup_dir: true };
        SliceStore::new(&cfg, 4, false).unwrap()
    }

    fn any_table(fmt: usize, rows: usize, dim: usize, seed: u64) -> AnyTable {
        let t = EmbeddingTable::randn(rows, dim, seed);
        match fmt {
            0 => AnyTable::F32(t),
            1 => AnyTable::Fused(t.quantize_fused(
                &GreedyQuantizer::default(),
                4,
                ScaleBiasDtype::F16,
            )),
            2 => AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32),
            ),
            _ => AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::TwoTier { k: 3 }, ScaleBiasDtype::F16),
            ),
        }
    }

    #[test]
    fn demote_promote_round_trip_every_format() {
        let store = tmp_store("round_trip", usize::MAX);
        for fmt in 0..4usize {
            let table = any_table(fmt, 24, 8, 0x70 + fmt as u64);
            let slice = TableSlice::cut(&table, 4..20);
            let mut want = vec![0.0f32; 8];
            slice.pool(&[0, 15, 7, 7], &mut want);
            let cell = store.admit(fmt % 4, fmt, slice);
            assert!(cell.is_resident());
            assert_eq!(store.demote_all().unwrap(), 1, "fmt {fmt}");
            assert!(!cell.is_resident());
            assert!(cell.spill_handle().unwrap().path().exists());
            let back = store.promote(&cell).unwrap();
            assert!(cell.is_resident());
            assert_eq!(back.rows(), 16);
            assert_eq!(back.global_rows(), 4..20);
            let mut got = vec![0.0f32; 8];
            back.pool(&[0, 15, 7, 7], &mut got);
            assert_eq!(got, want, "fmt {fmt}: reload must be bit-exact");
            // Drop the cell before the next format so the write-once
            // file is cleaned up.
            let path = cell.spill_handle().map(|h| h.path().to_path_buf());
            drop(back);
            drop(cell);
            if let Some(p) = path {
                assert!(!p.exists(), "fmt {fmt}: dropped cell must delete its file");
            }
        }
        let s = store.stats();
        assert_eq!(s.promotions, 4);
        assert_eq!(s.demotions, 4);
        assert!(s.spill_read_bytes > 0 && s.spill_write_bytes > 0);
        assert_eq!(s.spill_errors, 0);
    }

    #[test]
    fn second_demotion_reuses_the_file() {
        let store = tmp_store("write_once", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 16, 8, 0x80), 0..16);
        let cell = store.admit(0, 0, slice);
        assert_eq!(store.demote_all().unwrap(), 1);
        let written = store.stats().spill_write_bytes;
        assert!(written > 0);
        store.promote(&cell).unwrap();
        assert_eq!(store.demote_all().unwrap(), 1);
        assert_eq!(store.stats().spill_write_bytes, written, "write-once");
        assert_eq!(store.stats().demotions, 2);
    }

    #[test]
    fn budget_evicts_the_coldest_cell() {
        // Three equal slices, budget for two: after touching two of them
        // and enforcing, the untouched one must be the spilled one.
        let slice = |seed| TableSlice::cut(&any_table(0, 32, 8, seed), 0..32);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("coldest", 2 * bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        let c = store.admit(2, 2, slice(3));
        a.touch(100);
        c.touch(50);
        store.enforce();
        assert!(a.is_resident());
        assert!(!b.is_resident(), "the cold cell spills");
        assert!(c.is_resident());
        assert!(store.resident_bytes() <= 2 * bytes);
        // Touch b hard and promote: now the coldest of the others goes.
        b.touch(500);
        store.promote(&b).unwrap();
        assert!(b.is_resident());
        assert!(!c.is_resident(), "c (heat 50) is colder than a (heat 100)");
        assert!(store.resident_bytes() <= store.budget());
    }

    #[test]
    fn decay_tick_cools_spill_heat() {
        let slice = |seed| TableSlice::cut(&any_table(0, 16, 4, seed), 0..16);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("decay", bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        a.touch(1000); // old burst
        for _ in 0..12 {
            store.tick(); // 1000 decays to 0
        }
        b.touch(10); // fresh trickle beats fully decayed burst
        store.enforce();
        assert!(!a.is_resident());
        assert!(b.is_resident());
    }

    #[test]
    fn truncated_and_corrupt_files_are_clean_errors() {
        let store = tmp_store("corrupt", usize::MAX);
        let slice = TableSlice::cut(&any_table(1, 20, 16, 0x90), 0..20);
        let cell = store.admit(0, 0, slice);
        store.demote_all().unwrap();
        let path = cell.spill_handle().unwrap().path().to_path_buf();
        let good = fs::read(&path).unwrap();

        // Truncation.
        fs::write(&path, &good[..good.len() - 7]).unwrap();
        assert!(store.promote(&cell).is_err());
        assert!(!cell.is_resident());

        // Payload bit flip (length intact, checksum must catch it).
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x40;
        fs::write(&path, &flipped).unwrap();
        let err = store.promote(&cell).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("checksum"), "{err}");

        // Wrong magic.
        let mut wrong = good.clone();
        wrong[0] = b'X';
        fs::write(&path, &wrong).unwrap();
        assert!(store.promote(&cell).is_err());

        // Missing file entirely.
        fs::remove_file(&path).unwrap();
        assert!(store.promote(&cell).is_err());
        assert_eq!(store.stats().spill_errors, 4);
        assert_eq!(store.stats().promotions, 0);

        // Restore the original bytes: the cell recovers fully.
        fs::write(&path, &good).unwrap();
        assert!(store.promote(&cell).is_ok());
        assert!(cell.is_resident());
    }

    #[test]
    fn untracked_cells_never_spill_and_are_pinned() {
        let slice = TableSlice::cut(&any_table(0, 8, 4, 0xA0), 0..8);
        let cell = SliceCell::untracked(0, 0, slice);
        assert!(cell.is_resident());
        assert_eq!(cell.resident_bytes(), cell.bytes());
        assert_eq!(cell.rows(), 8);
        assert_eq!(cell.dim(), 4);
        // The untiered fast path: a plain borrow, no tier lock.
        let pinned = cell.pinned().expect("untracked cells pin their slice");
        assert_eq!(pinned.rows(), 8);
        // Store-tracked cells are not pinned (their tier can change).
        let store = tmp_store("pinned", usize::MAX);
        let tracked = store.admit(0, 0, TableSlice::cut(&any_table(0, 8, 4, 0xA1), 0..8));
        assert!(tracked.pinned().is_none());
    }

    #[test]
    fn promotion_fallback_tick_decays_without_a_rebalancer() {
        // Heat decays on the promotion path itself once the fallback
        // interval elapses — the budget-without-rebalancer configuration
        // must not degrade to all-time LFU.
        let slice = |seed| TableSlice::cut(&any_table(0, 16, 4, seed), 0..16);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("fallback_tick", bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        a.touch(1_000_000); // historically hot, then dead
        store.enforce(); // b spills (a is hotter)
        assert!(a.is_resident() && !b.is_resident());
        // Rewind the clock instead of sleeping: make the fallback
        // cadence consider a tick due, enough times that a's ancient
        // heat fully decays below fresh traffic.
        for _ in 0..25 {
            *lock_ignore_poison(&store.last_tick) = Instant::now() - HEAT_TICK_INTERVAL;
            let mut reg = lock_ignore_poison(&store.cells);
            store.maybe_tick_locked(&mut reg);
        }
        b.touch(10);
        store.promote(&b).unwrap();
        assert!(b.is_resident(), "fresh traffic wins");
        assert!(!a.is_resident(), "fully decayed history loses the budget");
    }

    #[test]
    fn external_ticks_lease_the_fallback_down_but_not_forever() {
        // Manual rebalance_once passes (no configured interval) also
        // drive store.tick(); while they keep arriving, the
        // promotion-path fallback must stand down or heat would decay on
        // two clocks. But the stand-down is a *lease*: once external
        // ticks stop for EXTERNAL_CLOCK_LEASE, the fallback resumes — a
        // one-off rebalance poke on a budget-only engine must not freeze
        // the heat clock for the rest of the process.
        let store = tmp_store("lease", usize::MAX); // fallback armed
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 8, 4, 0xB1), 0..8));
        a.touch(64);
        store.tick(); // an external clock takes over
        assert_eq!(a.heat_score(), 64);
        *lock_ignore_poison(&store.last_tick) = Instant::now() - HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.cells);
            store.maybe_tick_locked(&mut reg);
        }
        assert_eq!(a.heat_score(), 64, "no fallback decay inside the lease");
        // The external clock goes silent past its lease: the next
        // promotion-path check decays again.
        *lock_ignore_poison(&store.last_external_tick) =
            Some(Instant::now() - EXTERNAL_CLOCK_LEASE);
        *lock_ignore_poison(&store.last_tick) = Instant::now() - HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.cells);
            store.maybe_tick_locked(&mut reg);
        }
        assert_eq!(a.heat_score(), 32, "expired lease hands the clock back");
    }

    #[test]
    fn fallback_catches_up_one_halving_per_elapsed_interval() {
        // Heat decays by wall clock, not by promotion count: a long idle
        // gap applies every missed half-life at once, so a dead-but-
        // once-hot slice cannot outrank live traffic for dozens of
        // subsequent evictions.
        let store = tmp_store("catchup", usize::MAX);
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 8, 4, 0xB2), 0..8));
        a.touch(1 << 20);
        *lock_ignore_poison(&store.last_tick) = Instant::now() - 10 * HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.cells);
            store.maybe_tick_locked(&mut reg);
        }
        // The first catch-up tick folds the fresh burst (no halving),
        // the other nine halve it: 2^20 >> 9.
        assert_eq!(a.heat_score(), 1 << 11, "10 elapsed intervals, one catch-up pass");
        // And an absurd gap is capped at 64 ticks (enough to zero this
        // heat) instead of looping a million times.
        *lock_ignore_poison(&store.last_tick) =
            Instant::now() - 1_000_000 * HEAT_TICK_INTERVAL;
        {
            let mut reg = lock_ignore_poison(&store.cells);
            store.maybe_tick_locked(&mut reg);
        }
        assert_eq!(a.heat_score(), 0, "capped catch-up still decays stale heat to zero");
    }

    #[test]
    fn fallback_tick_is_inert_when_a_rebalancer_owns_the_cadence() {
        // With rebalancer_ticks the spill heat must cool on the
        // rebalancer's clock only, or replicas of a still-hot table
        // would cool ahead of the table score that justified them.
        let dir = std::env::temp_dir()
            .join(format!("emberq_store_test_inert_{}", std::process::id()));
        let cfg = SpillConfig { dir, resident_budget: usize::MAX, cleanup_dir: true };
        let store = SliceStore::new(&cfg, 4, true).unwrap();
        let a = store.admit(0, 0, TableSlice::cut(&any_table(0, 16, 4, 0xB0), 0..16));
        a.touch(100);
        *lock_ignore_poison(&store.last_tick) = Instant::now() - 10 * HEAT_TICK_INTERVAL;
        let mut reg = lock_ignore_poison(&store.cells);
        store.maybe_tick_locked(&mut reg);
        drop(reg);
        assert_eq!(a.heat_score(), 100, "no promotion-path decay");
        store.tick(); // the rebalancer's tick folds and decays as usual
        assert_eq!(a.heat_score(), 100);
        store.tick();
        assert_eq!(a.heat_score(), 50);
    }

    #[test]
    fn promotion_protects_the_touched_cell() {
        // Budget of one slice: promoting a spilled cell must evict the
        // other resident cell, not immediately re-evict itself.
        let slice = |seed| TableSlice::cut(&any_table(0, 16, 8, seed), 0..16);
        let bytes = slice(1).size_bytes();
        let store = tmp_store("protect", bytes);
        let a = store.admit(0, 0, slice(1));
        let b = store.admit(1, 1, slice(2));
        a.touch(10);
        store.enforce();
        assert!(a.is_resident() && !b.is_resident());
        store.promote(&b).unwrap();
        assert!(b.is_resident(), "the freshly promoted cell stays");
        assert!(!a.is_resident(), "the other one pays");
        assert!(store.resident_bytes() <= bytes);
    }
}
