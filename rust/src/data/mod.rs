//! Synthetic Criteo-Terabyte-like click-log generator.
//!
//! The paper evaluates on the Terabyte Criteo click-prediction dataset
//! (1.3 TB, 4.3 B records, proprietary-scale) — unavailable here, so we
//! generate the closest synthetic equivalent that exercises the same code
//! paths (DESIGN.md "What the paper needs → what we build"):
//!
//! * 13 dense features and 26 categorical features, like Criteo;
//! * categorical ids drawn from a **Zipf** distribution per feature (click
//!   logs have long-tail id popularity — hot ids dominate lookups);
//! * labels from a fixed **teacher model**: a logistic function over
//!   per-id latent scalars (deterministic hash), dense features, and a
//!   feature cross, so the task is learnable but not linearly trivial and
//!   quantization-induced quality deltas are measurable.
//!
//! Everything is seeded: train/eval streams are disjoint deterministic
//! RNG forks, so every experiment regenerates bit-identically.

pub mod trace;

pub use trace::{RequestTrace, TraceConfig};

use crate::util::rng::{Rng, Zipf};

/// Criteo-like dataset configuration.
#[derive(Clone, Debug)]
pub struct CriteoConfig {
    /// Number of dense (numeric) features. Criteo: 13.
    pub dense_dim: usize,
    /// Number of categorical features / embedding tables. Criteo: 26.
    pub num_sparse: usize,
    /// Cardinality of each categorical feature (rows per table).
    pub rows_per_table: usize,
    /// Zipf exponent of id popularity.
    pub zipf_alpha: f64,
    /// Master seed; train/eval derive disjoint streams from it.
    pub seed: u64,
}

impl Default for CriteoConfig {
    fn default() -> Self {
        CriteoConfig {
            dense_dim: 13,
            num_sparse: 26,
            rows_per_table: 100_000,
            zipf_alpha: 1.05,
            seed: 0x0C11C7E0,
        }
    }
}

/// One mini-batch of click records.
#[derive(Clone, Debug)]
pub struct ClickBatch {
    /// Dense features, `batch × dense_dim` row-major.
    pub dense: Vec<f32>,
    /// One id per (feature, record): `ids[f][b]`.
    pub ids: Vec<Vec<u32>>,
    /// Click labels in `{0.0, 1.0}`.
    pub labels: Vec<f32>,
    /// Batch size.
    pub batch: usize,
}

/// Deterministic synthetic click-log stream.
pub struct SyntheticCriteo {
    cfg: CriteoConfig,
    zipf: Zipf,
    rng: Rng,
    /// Per-feature weight of the latent scalar in the teacher logit.
    feature_w: Vec<f32>,
    /// Teacher weights for dense features.
    dense_w: Vec<f32>,
}

/// Deterministic per-(feature, id) latent scalar in `[-1, 1)`.
///
/// This is the "ground truth" embedding the teacher uses and the student
/// must recover; a hash avoids materializing `num_sparse × rows` floats.
#[inline]
pub fn latent(feature: usize, id: u32, seed: u64) -> f32 {
    let mut z = seed ^ (feature as u64) << 32 ^ id as u64;
    z = z.wrapping_mul(0x9E3779B97F4A7C15);
    z ^= z >> 29;
    z = z.wrapping_mul(0xBF58476D1CE4E5B9);
    z ^= z >> 32;
    // Map the top 24 bits to [-1, 1).
    ((z >> 40) as f32) / (1u64 << 23) as f32 - 1.0
}

impl SyntheticCriteo {
    /// Build the stream with the given role ("train" vs "eval" fork).
    pub fn new(cfg: CriteoConfig, stream: u64) -> Self {
        let mut master = Rng::new(cfg.seed);
        let mut teacher_rng = master.fork(0x7EAC4E12);
        let feature_w = (0..cfg.num_sparse)
            .map(|_| teacher_rng.uniform_in(0.5, 1.5) as f32)
            .collect();
        let dense_w = (0..cfg.dense_dim)
            .map(|_| teacher_rng.uniform_in(-0.5, 0.5) as f32)
            .collect();
        let rng = master.fork(stream);
        let zipf = Zipf::new(cfg.rows_per_table, cfg.zipf_alpha);
        SyntheticCriteo { cfg, zipf, rng, feature_w, dense_w }
    }

    /// Convenience: training stream.
    pub fn train(cfg: CriteoConfig) -> Self {
        Self::new(cfg, 1)
    }

    /// Convenience: held-out evaluation stream.
    pub fn eval(cfg: CriteoConfig) -> Self {
        Self::new(cfg, 2)
    }

    /// The configuration.
    pub fn config(&self) -> &CriteoConfig {
        &self.cfg
    }

    /// Teacher click probability for one record.
    fn teacher_prob(&self, dense: &[f32], ids: &[u32]) -> f32 {
        let seed = self.cfg.seed;
        let mut logit = -0.3f32; // base CTR below 50%
        for (f, &id) in ids.iter().enumerate() {
            logit += self.feature_w[f] * latent(f, id, seed);
        }
        for (j, &x) in dense.iter().enumerate() {
            logit += self.dense_w[j] * x;
        }
        // A feature cross: the first two categorical features interact.
        if ids.len() >= 2 {
            logit += 1.5 * latent(0, ids[0], seed) * latent(1, ids[1], seed);
        }
        1.0 / (1.0 + (-logit).exp())
    }

    /// Draw the next mini-batch.
    pub fn next_batch(&mut self, batch: usize) -> ClickBatch {
        let cfg = self.cfg.clone();
        let mut dense = Vec::with_capacity(batch * cfg.dense_dim);
        let mut ids: Vec<Vec<u32>> = vec![Vec::with_capacity(batch); cfg.num_sparse];
        let mut labels = Vec::with_capacity(batch);
        for _ in 0..batch {
            let rec_dense: Vec<f32> =
                (0..cfg.dense_dim).map(|_| self.rng.normal() as f32).collect();
            let rec_ids: Vec<u32> =
                (0..cfg.num_sparse).map(|_| self.zipf.sample(&mut self.rng) as u32).collect();
            let p = self.teacher_prob(&rec_dense, &rec_ids);
            let y = if (self.rng.uniform() as f32) < p { 1.0 } else { 0.0 };
            dense.extend_from_slice(&rec_dense);
            for (f, &id) in rec_ids.iter().enumerate() {
                ids[f].push(id);
            }
            labels.push(y);
        }
        ClickBatch { dense, ids, labels, batch }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> CriteoConfig {
        CriteoConfig {
            dense_dim: 4,
            num_sparse: 3,
            rows_per_table: 1000,
            zipf_alpha: 1.1,
            seed: 99,
        }
    }

    #[test]
    fn batch_shapes() {
        let mut s = SyntheticCriteo::train(small_cfg());
        let b = s.next_batch(32);
        assert_eq!(b.batch, 32);
        assert_eq!(b.dense.len(), 32 * 4);
        assert_eq!(b.ids.len(), 3);
        assert!(b.ids.iter().all(|f| f.len() == 32));
        assert!(b.labels.iter().all(|&y| y == 0.0 || y == 1.0));
        assert!(b.ids.iter().flatten().all(|&i| i < 1000));
    }

    #[test]
    fn deterministic_and_stream_disjoint() {
        let a1 = SyntheticCriteo::train(small_cfg()).next_batch(16);
        let a2 = SyntheticCriteo::train(small_cfg()).next_batch(16);
        assert_eq!(a1.labels, a2.labels);
        assert_eq!(a1.ids, a2.ids);
        let e = SyntheticCriteo::eval(small_cfg()).next_batch(16);
        assert_ne!(a1.ids, e.ids);
    }

    #[test]
    fn labels_not_degenerate() {
        let mut s = SyntheticCriteo::train(small_cfg());
        let b = s.next_batch(2000);
        let pos: f32 = b.labels.iter().sum::<f32>() / 2000.0;
        assert!(pos > 0.1 && pos < 0.9, "positive rate {pos}");
    }

    #[test]
    fn latent_deterministic_and_bounded() {
        for f in 0..5 {
            for id in [0u32, 1, 999_999] {
                let v = latent(f, id, 7);
                assert_eq!(v, latent(f, id, 7));
                assert!((-1.0..1.0).contains(&v), "v={v}");
            }
        }
        assert_ne!(latent(0, 1, 7), latent(1, 1, 7));
        assert_ne!(latent(0, 1, 7), latent(0, 2, 7));
    }

    #[test]
    fn labels_learnable_from_latents() {
        // A logistic model on the *true* latents must beat the base-rate
        // log loss — i.e. the labels carry signal.
        let mut s = SyntheticCriteo::train(small_cfg());
        let b = s.next_batch(4000);
        let mut ll_teacher = 0.0f64;
        let mut ll_base = 0.0f64;
        let base: f32 = b.labels.iter().sum::<f32>() / b.batch as f32;
        for r in 0..b.batch {
            let ids: Vec<u32> = (0..3).map(|f| b.ids[f][r]).collect();
            let dense = &b.dense[r * 4..(r + 1) * 4];
            let p = s.teacher_prob(dense, &ids).clamp(1e-6, 1.0 - 1e-6);
            let y = b.labels[r] as f64;
            ll_teacher -= y * (p as f64).ln() + (1.0 - y) * (1.0 - p as f64).ln();
            ll_base -= y * (base as f64).ln() + (1.0 - y) * (1.0 - base as f64).ln();
        }
        assert!(
            ll_teacher < ll_base * 0.95,
            "teacher {ll_teacher} vs base {ll_base}"
        );
    }
}
