//! Serving request-trace generation for the coordinator benchmarks.
//!
//! A trace is a sequence of embedding-lookup requests shaped like
//! production ranking traffic: each request pools a variable number of
//! Zipf-popular ids per table (candidate sets), so hot rows hit cache and
//! the tail streams from memory — the access mix Table 1's "non-resident"
//! column models.

use crate::util::rng::{Rng, Zipf};

/// Trace shape parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    /// Number of requests.
    pub requests: usize,
    /// Tables each request touches.
    pub num_tables: usize,
    /// Rows per table (id space).
    pub rows: usize,
    /// Mean pooled ids per table per request.
    pub mean_pool: usize,
    /// Zipf exponent for id popularity.
    pub zipf_alpha: f64,
    /// Seed.
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            requests: 10_000,
            num_tables: 8,
            rows: 100_000,
            mean_pool: 20,
            zipf_alpha: 1.05,
            seed: 0x7124CE,
        }
    }
}

/// One lookup request: per-table pooled id lists.
#[derive(Clone, Debug)]
pub struct Request {
    /// `ids[t]` are the rows pooled from table `t`.
    pub ids: Vec<Vec<u32>>,
}

/// A generated trace.
pub struct RequestTrace {
    /// The requests, in arrival order.
    pub requests: Vec<Request>,
}

impl RequestTrace {
    /// Generate a trace.
    pub fn generate(cfg: &TraceConfig) -> Self {
        let mut rng = Rng::new(cfg.seed);
        let zipf = Zipf::new(cfg.rows, cfg.zipf_alpha);
        let requests = (0..cfg.requests)
            .map(|_| {
                let ids = (0..cfg.num_tables)
                    .map(|_| {
                        // Pool size: 1 + Geometric-ish around mean_pool.
                        let len = 1 + rng.below(cfg.mean_pool * 2);
                        (0..len).map(|_| zipf.sample(&mut rng) as u32).collect()
                    })
                    .collect();
                Request { ids }
            })
            .collect();
        RequestTrace { requests }
    }

    /// Total pooled lookups across the trace (for throughput accounting).
    pub fn total_lookups(&self) -> usize {
        self.requests
            .iter()
            .map(|r| r.ids.iter().map(Vec::len).sum::<usize>())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let cfg = TraceConfig { requests: 100, num_tables: 4, rows: 1000, ..Default::default() };
        let t = RequestTrace::generate(&cfg);
        assert_eq!(t.requests.len(), 100);
        for r in &t.requests {
            assert_eq!(r.ids.len(), 4);
            for ids in &r.ids {
                assert!(!ids.is_empty());
                assert!(ids.iter().all(|&i| i < 1000));
            }
        }
        assert!(t.total_lookups() > 0);
    }

    #[test]
    fn deterministic() {
        let cfg = TraceConfig { requests: 50, ..Default::default() };
        let a = RequestTrace::generate(&cfg);
        let b = RequestTrace::generate(&cfg);
        assert_eq!(a.requests[7].ids, b.requests[7].ids);
    }

    #[test]
    fn zipf_skew_visible() {
        let cfg = TraceConfig {
            requests: 2000,
            num_tables: 1,
            rows: 10_000,
            mean_pool: 10,
            zipf_alpha: 1.2,
            seed: 5,
        };
        let t = RequestTrace::generate(&cfg);
        let mut hits_low = 0usize;
        let mut total = 0usize;
        for r in &t.requests {
            for &id in &r.ids[0] {
                if id < 100 {
                    hits_low += 1;
                }
                total += 1;
            }
        }
        // The hottest 1% of ids should get far more than 1% of traffic.
        assert!(hits_low * 10 > total, "{hits_low}/{total}");
    }
}
