//! Deterministic chaos/scenario harness for the sharded serving engine.
//!
//! Real embedding tiers fail in boring, repeated ways: a worker thread
//! panics, a spill file is corrupted or truncated under the server, the
//! spill volume fills or disappears, the background I/O pool wedges,
//! the precision rebalancer re-quantizes tables mid-traffic — all while
//! the model keeps taking live row updates. This module turns those
//! failures into *scenarios*: a seeded, replayable schedule of Zipf +
//! diurnal traffic, concurrent [`update_table`] writers, and fault
//! injections (including [`FaultKind::RequantStorm`] online format
//! flips in lockstep with the oracle), with the invariants the rest of
//! the crate promises checked continuously:
//!
//! * **Bit-exactness** — every lookup observed outside a destructive
//!   fault window must equal the unsharded oracle
//!   ([`VersionedOracle`]) at *some* single snapshot version in the
//!   `[version-before, version-after]` window of the read. No request
//!   may ever observe a mix of two table versions.
//! * **Recovery** — after each fault heals, a probe must serve
//!   bit-exactly again (and the final full-table sweep must match the
//!   oracle at the final version exactly).
//! * **Budget** — with a resident budget configured, RAM-resident slice
//!   bytes stay at or under it at rest, and the resident + spilled
//!   tiers always reconcile to the logical table bytes.
//! * **Version monotonicity** — [`ShardedEngine::version`] never moves
//!   backwards, and the per-shard stats frames report the same version.
//!
//! Everything is derived from [`ScenarioConfig::seed`]: traffic,
//! update payloads, and the fault schedule. Two runs of the same config
//! produce the same [`ScenarioReport`] — the integration suite asserts
//! this, so a scenario failure reproduces under its printed seed.
//! Concurrency (reader/updater threads) is real; determinism is kept by
//! reporting only schedule-derived facts and checking race-dependent
//! observations against windows instead of point values.
//!
//! See `docs/serving.md` ("Chaos harness") for running scenarios and
//! writing new ones.
//!
//! [`update_table`]: crate::shard::ShardedEngine::update_table
//! [`ShardedEngine::version`]: crate::shard::ShardedEngine::version

mod oracle;
mod scenario;
mod traffic;

pub use oracle::VersionedOracle;
pub use scenario::{run_scenario, FaultKind, ScenarioConfig, ScenarioReport};
pub use traffic::DiurnalTraffic;
