//! Seeded traffic shapes: Zipf-skewed ids under a diurnal batch-size
//! envelope.
//!
//! Production embedding traffic is doubly non-uniform: *which* rows are
//! touched follows a power law (a few ids absorb most lookups), and
//! *how much* traffic arrives swings sinusoidally over the day. The
//! scenario driver replays both shapes from one seed so a chaos run is
//! a pure function of its [`ScenarioConfig`](super::ScenarioConfig).

use crate::data::trace::Request;
use crate::util::{Rng, Zipf};

/// Deterministic request generator: per-tick batch sizes follow a
/// sinusoidal "diurnal" envelope, per-table pooled ids follow a Zipf
/// law over the row space.
///
/// Determinism contract: `tick` draws from the owned [`Rng`] in a fixed
/// order, so two `DiurnalTraffic` instances built with the same
/// parameters and ticked with the same sequence of tick numbers yield
/// identical request streams. Call it from a single driver thread.
pub struct DiurnalTraffic {
    rng: Rng,
    zipf: Zipf,
    tables: usize,
    base_batch: usize,
    period: usize,
    mean_pool: usize,
}

impl DiurnalTraffic {
    /// A generator over `tables` tables of `rows` rows each.
    ///
    /// `base_batch` is the mean requests per tick (the envelope swings
    /// it by ±75%), `period` is the diurnal cycle length in ticks, and
    /// `mean_pool` the mean pooled ids per table per request.
    pub fn new(
        seed: u64,
        tables: usize,
        rows: usize,
        base_batch: usize,
        period: usize,
        mean_pool: usize,
        zipf_alpha: f64,
    ) -> Self {
        assert!(tables > 0 && rows > 0 && base_batch > 0 && period > 0 && mean_pool > 0);
        DiurnalTraffic {
            rng: Rng::new(seed),
            zipf: Zipf::new(rows, zipf_alpha),
            tables,
            base_batch,
            period,
            mean_pool,
        }
    }

    /// Requests arriving in tick `tick` (at least one).
    pub fn tick(&mut self, tick: usize) -> Vec<Request> {
        let phase = (tick % self.period) as f64 / self.period as f64;
        let envelope = 1.0 + 0.75 * (phase * std::f64::consts::TAU).sin();
        let batch = ((self.base_batch as f64 * envelope).round() as usize).max(1);
        (0..batch)
            .map(|_| {
                let ids = (0..self.tables)
                    .map(|_| {
                        let pool = 1 + self.rng.below(self.mean_pool * 2);
                        (0..pool).map(|_| self.zipf.sample(&mut self.rng) as u32).collect()
                    })
                    .collect();
                Request { ids }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn traffic_is_deterministic_and_diurnal() {
        let run = |seed| {
            let mut t = DiurnalTraffic::new(seed, 2, 100, 8, 16, 4, 1.2);
            (0..32).map(|i| t.tick(i)).collect::<Vec<_>>()
        };
        let a = run(7);
        assert_eq!(
            a.iter()
                .map(|b| b.iter().map(|r| r.ids.clone()).collect::<Vec<_>>())
                .collect::<Vec<_>>(),
            run(7)
                .iter()
                .map(|b| b.iter().map(|r| r.ids.clone()).collect::<Vec<_>>())
                .collect::<Vec<_>>(),
            "same seed, same stream"
        );
        // The envelope actually swings: the peak tick (period/4) carries
        // more requests than the trough (3*period/4).
        assert!(a[4].len() > a[12].len(), "peak {} vs trough {}", a[4].len(), a[12].len());
        // Every id is in range and every request touches every table.
        for batch in &a {
            for req in batch {
                assert_eq!(req.ids.len(), 2);
                for ids in &req.ids {
                    assert!(!ids.is_empty());
                    assert!(ids.iter().all(|&i| (i as usize) < 100));
                }
            }
        }
        // Zipf skew: id 0 must dominate a uniform share by a wide margin.
        let all: Vec<u32> =
            a.iter().flatten().flat_map(|r| r.ids.iter().flatten().copied()).collect();
        let zeros = all.iter().filter(|&&i| i == 0).count();
        assert!(zeros * 20 > all.len(), "{} of {} ids hit row 0", zeros, all.len());
    }
}
