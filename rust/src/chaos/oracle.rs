//! Versioned unsharded oracle: the ground truth a chaos scenario
//! compares the sharded engine against.
//!
//! The oracle keeps the FP32 master tables plus one immutable quantized
//! [`TableSet`] snapshot *per committed version*, mirroring the
//! engine's MVCC swap protocol: a snapshot for version `v` is published
//! **before** the engine can report `version() == v`, so a reader that
//! observes engine version `v` can always fetch the matching oracle
//! snapshot. Commits serialize on an internal mutex — the same total
//! order the engine imposes through its own update lock — which makes
//! "engine version n == oracle snapshot n" hold by construction.
//!
//! Bit-exactness leans on an invariant proven in the `shard::engine`
//! tests: patching a fused row with
//! [`quantize_row_fused`](crate::table::quantize_row_fused) is
//! bit-identical to requantizing the whole patched FP32 table. The
//! oracle therefore patches its FP32 masters and requantizes from
//! scratch per commit, while the engine patches packed rows in place —
//! two different code paths that must (and do) land on identical bytes.

use std::io;
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::TableSet;
use crate::data::trace::Request;
use crate::quant::Quantizer;
use crate::table::serial::AnyTable;
use crate::table::{EmbeddingTable, ScaleBiasDtype};

/// Unsharded reference store with one quantized snapshot per version.
pub struct VersionedOracle {
    /// FP32 masters; the mutex also serializes commits.
    masters: Mutex<Vec<EmbeddingTable>>,
    /// `snapshots[v]` is the quantized set at version `v`. Versions
    /// start at 1, so index 0 holds a duplicate of version 1.
    snapshots: RwLock<Vec<Arc<TableSet>>>,
    nbits: u32,
    sb: ScaleBiasDtype,
}

impl VersionedOracle {
    /// Build from FP32 masters, quantizing each table to fused rows.
    pub fn new(masters: Vec<EmbeddingTable>, q: &dyn Quantizer, nbits: u32, sb: ScaleBiasDtype) -> Self {
        let v1 = Arc::new(Self::quantize(&masters, q, nbits, sb));
        VersionedOracle {
            masters: Mutex::new(masters),
            snapshots: RwLock::new(vec![Arc::clone(&v1), v1]),
            nbits,
            sb,
        }
    }

    fn quantize(
        masters: &[EmbeddingTable],
        q: &dyn Quantizer,
        nbits: u32,
        sb: ScaleBiasDtype,
    ) -> TableSet {
        TableSet::new(
            masters.iter().map(|m| AnyTable::Fused(m.quantize_fused(q, nbits, sb))).collect(),
        )
    }

    /// A fresh quantized set for starting an engine. Bit-identical to
    /// snapshot 1, so only meaningful before the first [`commit`].
    ///
    /// [`commit`]: VersionedOracle::commit
    pub fn quantized_set(&self, q: &dyn Quantizer) -> TableSet {
        // lint:allow(raw_lock) — poison must propagate: a panic mid-commit
        // leaves half-patched masters, and recovering would serve them.
        Self::quantize(&self.masters.lock().unwrap(), q, self.nbits, self.sb)
    }

    /// Latest committed version.
    pub fn latest_version(&self) -> u64 {
        // lint:allow(raw_lock) — poison must propagate (see commit).
        self.snapshots.read().unwrap().len() as u64 - 1
    }

    /// Apply one update batch through the engine while keeping the
    /// oracle in lockstep.
    ///
    /// `apply` performs the engine-side swap (typically a closure over
    /// [`ShardedEngine::update_table`]); the oracle publishes its own
    /// speculative snapshot for the expected new version *first*, so a
    /// reader that races the swap and observes the new engine version
    /// already finds the matching snapshot. On `Err` the speculative
    /// snapshot is retracted and the masters are rolled back — readers
    /// cannot have used it, because the engine never reported the
    /// version it would have carried.
    ///
    /// [`ShardedEngine::update_table`]: crate::shard::ShardedEngine::update_table
    pub fn commit<F>(
        &self,
        table: usize,
        rows: &[(u32, Vec<f32>)],
        q: &dyn Quantizer,
        apply: F,
    ) -> io::Result<u64>
    where
        F: FnOnce() -> io::Result<u64>,
    {
        // lint:allow(raw_lock) — deliberately poison-propagating: an
        // updater that panics mid-commit leaves the masters half-patched,
        // and every later oracle call MUST fail loudly, not serve them.
        let mut masters = self.masters.lock().unwrap();
        let valid = table < masters.len()
            && rows.iter().all(|(id, v)| {
                (*id as usize) < masters[table].rows() && v.len() == masters[table].dim()
            });
        if !valid {
            // The engine rejects malformed updates without swapping, so
            // the oracle has nothing to mirror or roll back.
            let r = apply();
            debug_assert!(r.is_err(), "engine accepted an update the oracle rejected");
            return r;
        }
        // Patch the masters speculatively, remembering the old rows.
        let saved: Vec<(u32, Vec<f32>)> =
            rows.iter().map(|(id, _)| (*id, masters[table].row(*id as usize).to_vec())).collect();
        for (id, vals) in rows {
            masters[table].row_mut(*id as usize).copy_from_slice(vals);
        }
        let candidate = Arc::new(Self::quantize(&masters, q, self.nbits, self.sb));
        let expected = {
            // lint:allow(raw_lock) — poison must propagate (see above).
            let mut snaps = self.snapshots.write().unwrap();
            let expected = snaps.len() as u64;
            snaps.push(candidate);
            expected
        };
        match apply() {
            Ok(v) => {
                assert_eq!(v, expected, "engine and oracle versions diverged");
                Ok(v)
            }
            Err(e) => {
                for (id, old) in &saved {
                    masters[table].row_mut(*id as usize).copy_from_slice(old);
                }
                // lint:allow(raw_lock) — poison must propagate (see above).
                let mut snaps = self.snapshots.write().unwrap();
                assert_eq!(snaps.len() as u64, expected + 1, "commit serialization broken");
                snaps.pop();
                Err(e)
            }
        }
    }

    /// Pooled lookup against the snapshot at `version` (panics if the
    /// version was never committed).
    pub fn pool_at(&self, version: u64, req: &Request) -> Vec<f32> {
        // lint:allow(raw_lock) — poison must propagate (see commit).
        let set = Arc::clone(&self.snapshots.read().unwrap()[version as usize]);
        let mut out = vec![0.0f32; set.feature_width()];
        for t in 0..set.num_tables() {
            if req.ids[t].is_empty() {
                continue;
            }
            let lo = set.offset_of(t);
            let hi = lo + set.dim_of(t);
            set.pool(t, &req.ids[t], &mut out[lo..hi]);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GreedyQuantizer;
    use crate::shard::{ShardConfig, ShardedEngine};

    fn masters(n: usize, rows: usize, dim: usize) -> Vec<EmbeddingTable> {
        (0..n).map(|t| EmbeddingTable::randn(rows, dim, 4300 + t as u64)).collect()
    }

    #[test]
    fn oracle_tracks_engine_versions_bit_exactly() {
        let q = GreedyQuantizer::default();
        let oracle = VersionedOracle::new(masters(2, 24, 4), &q, 4, ScaleBiasDtype::F16);
        let engine = ShardedEngine::start(
            oracle.quantized_set(&q),
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..ShardConfig::default() },
        );
        let req = Request { ids: vec![vec![0, 3, 23], vec![5, 5]] };
        assert_eq!(engine.lookup(&req), oracle.pool_at(1, &req), "version 1 agrees");

        let rows: Vec<(u32, Vec<f32>)> = vec![(3, vec![0.5; 4]), (17, vec![-1.25; 4])];
        let v = oracle
            .commit(0, &rows, &q, || engine.update_table(0, &rows, &q))
            .expect("commit succeeds");
        assert_eq!(v, 2);
        assert_eq!(oracle.latest_version(), 2);
        assert_eq!(engine.version(), 2);
        let req2 = Request { ids: vec![vec![3, 17], vec![1]] };
        assert_eq!(engine.lookup(&req2), oracle.pool_at(2, &req2), "version 2 agrees");
        // The old snapshot is still readable and still different.
        assert_ne!(oracle.pool_at(1, &req2), oracle.pool_at(2, &req2));
    }

    #[test]
    fn failed_commits_are_rolled_back() {
        let q = GreedyQuantizer::default();
        let oracle = VersionedOracle::new(masters(1, 16, 4), &q, 4, ScaleBiasDtype::F16);
        let engine = ShardedEngine::start(
            oracle.quantized_set(&q),
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..ShardConfig::default() },
        );
        let before = oracle.pool_at(1, &Request { ids: vec![vec![2]] });
        // A valid-looking batch whose apply fails mid-swap.
        let rows: Vec<(u32, Vec<f32>)> = vec![(2, vec![9.0; 4])];
        let err = oracle
            .commit(0, &rows, &q, || Err(io::Error::new(io::ErrorKind::Other, "injected")))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Other);
        assert_eq!(oracle.latest_version(), 1, "speculative snapshot retracted");
        assert_eq!(
            oracle.pool_at(1, &Request { ids: vec![vec![2]] }),
            before,
            "masters rolled back"
        );
        // A malformed batch is rejected by the engine and leaves no trace.
        let bad: Vec<(u32, Vec<f32>)> = vec![(999, vec![1.0; 4])];
        assert!(oracle.commit(0, &bad, &q, || engine.update_table(0, &bad, &q)).is_err());
        assert_eq!(oracle.latest_version(), 1);
        // After all that, a real commit still lands cleanly at version 2.
        let v = oracle.commit(0, &rows, &q, || engine.update_table(0, &rows, &q)).unwrap();
        assert_eq!(v, 2);
        let req = Request { ids: vec![vec![2]] };
        assert_eq!(engine.lookup(&req), oracle.pool_at(2, &req));
    }
}
