//! Versioned unsharded oracle: the ground truth a chaos scenario
//! compares the sharded engine against.
//!
//! The oracle keeps one immutable quantized [`TableSet`] snapshot *per
//! committed version*, mirroring the engine's MVCC swap protocol: a
//! snapshot for version `v` is published **before** the engine can
//! report `version() == v`, so a reader that observes engine version
//! `v` can always fetch the matching oracle snapshot. Commits serialize
//! on an internal mutex — the same total order the engine imposes
//! through its own update lock — which makes "engine version n ==
//! oracle snapshot n" hold by construction.
//!
//! Two kinds of commit advance the state:
//!
//! * **Row updates** ([`VersionedOracle::commit`]). While a table is
//!   still in its ingest format, the oracle patches its FP32 master and
//!   requantizes the whole table from scratch, leaning on an invariant
//!   proven in the `shard::engine` tests: patching a fused row with
//!   [`quantize_row_fused`](crate::table::quantize_row_fused) is
//!   bit-identical to requantizing the whole patched FP32 table. The
//!   engine patches packed rows in place — two different code paths
//!   that must (and do) land on identical bytes.
//! * **Online re-quantization** ([`VersionedOracle::commit_requant`]).
//!   A requant storm drives the engine's
//!   [`requantize_to`](crate::shard::ShardedEngine::requantize_to)
//!   swap; the oracle mirrors it by re-encoding its current image of
//!   the table through the same single re-quantization path
//!   ([`crate::quant::budget::build_table`]) — from the *de-quantized
//!   current bytes*, not the FP32 master, because the engine's online
//!   pass never sees the master either. From then on the table's format
//!   has drifted from the ingest epoch, so later row updates on it
//!   patch the quantized image per row exactly the way the engine does.
//!   Fused per-row quantization is row-local, so the oracle's
//!   whole-table image stays byte-identical to the concatenation of the
//!   engine's per-chunk rebuilds.

use std::io;
use std::sync::{Arc, Mutex, RwLock};

use crate::coordinator::catalog::FormatTag;
use crate::coordinator::TableSet;
use crate::data::trace::Request;
use crate::quant::{budget, Quantizer};
use crate::table::serial::AnyTable;
use crate::table::{quantize_row_fused, EmbeddingTable, FusedTable, ScaleBiasDtype};

/// The mutable half of the oracle; its mutex also serializes commits.
struct OracleState {
    /// FP32 ground truth of every committed row update.
    masters: Vec<EmbeddingTable>,
    /// Authoritative quantized image per table, mirroring the engine's
    /// serving bytes at the latest version.
    current: Vec<AnyTable>,
    /// Tables whose format drifted from the ingest epoch via
    /// [`VersionedOracle::commit_requant`]: updates on them must patch
    /// `current` instead of requantizing the master from scratch.
    requantized: Vec<bool>,
}

/// Unsharded reference store with one quantized snapshot per version.
pub struct VersionedOracle {
    state: Mutex<OracleState>,
    /// `snapshots[v]` is the quantized set at version `v`. Versions
    /// start at 1, so index 0 holds a duplicate of version 1.
    snapshots: RwLock<Vec<Arc<TableSet>>>,
    nbits: u32,
    sb: ScaleBiasDtype,
}

impl VersionedOracle {
    /// Build from FP32 masters, quantizing each table to fused rows.
    pub fn new(
        masters: Vec<EmbeddingTable>,
        q: &dyn Quantizer,
        nbits: u32,
        sb: ScaleBiasDtype,
    ) -> Self {
        let current: Vec<AnyTable> =
            masters.iter().map(|m| AnyTable::Fused(m.quantize_fused(q, nbits, sb))).collect();
        let v1 = Arc::new(TableSet::new(current.clone()));
        let requantized = vec![false; masters.len()];
        VersionedOracle {
            state: Mutex::new(OracleState { masters, current, requantized }),
            snapshots: RwLock::new(vec![Arc::clone(&v1), v1]),
            nbits,
            sb,
        }
    }

    /// A fresh quantized set for starting an engine. Bit-identical to
    /// snapshot 1, so only meaningful before the first [`commit`].
    ///
    /// [`commit`]: VersionedOracle::commit
    pub fn quantized_set(&self) -> TableSet {
        // lint:allow(raw_lock) — poison must propagate: a panic mid-commit
        // leaves half-patched state, and recovering would serve it.
        TableSet::new(self.state.lock().unwrap().current.clone())
    }

    /// Latest committed version.
    pub fn latest_version(&self) -> u64 {
        // lint:allow(raw_lock) — poison must propagate (see commit).
        self.snapshots.read().unwrap().len() as u64 - 1
    }

    /// Apply one update batch through the engine while keeping the
    /// oracle in lockstep.
    ///
    /// `apply` performs the engine-side swap (typically a closure over
    /// [`ShardedEngine::update_table`]); the oracle publishes its own
    /// speculative snapshot for the expected new version *first*, so a
    /// reader that races the swap and observes the new engine version
    /// already finds the matching snapshot. On `Err` the speculative
    /// snapshot is retracted and the state is rolled back — readers
    /// cannot have used it, because the engine never reported the
    /// version it would have carried.
    ///
    /// [`ShardedEngine::update_table`]: crate::shard::ShardedEngine::update_table
    pub fn commit<F>(
        &self,
        table: usize,
        rows: &[(u32, Vec<f32>)],
        q: &dyn Quantizer,
        apply: F,
    ) -> io::Result<u64>
    where
        F: FnOnce() -> io::Result<u64>,
    {
        // lint:allow(raw_lock) — deliberately poison-propagating: an
        // updater that panics mid-commit leaves the state half-patched,
        // and every later oracle call MUST fail loudly, not serve it.
        let mut st = self.state.lock().unwrap();
        let valid = table < st.masters.len()
            && rows.iter().all(|(id, v)| {
                (*id as usize) < st.masters[table].rows() && v.len() == st.masters[table].dim()
            });
        if !valid {
            // The engine rejects malformed updates without swapping, so
            // the oracle has nothing to mirror or roll back.
            let r = apply();
            debug_assert!(r.is_err(), "engine accepted an update the oracle rejected");
            return r;
        }
        // Patch the state speculatively, remembering the old rows.
        let saved: Vec<(u32, Vec<f32>)> = rows
            .iter()
            .map(|(id, _)| (*id, st.masters[table].row(*id as usize).to_vec()))
            .collect();
        for (id, vals) in rows {
            st.masters[table].row_mut(*id as usize).copy_from_slice(vals);
        }
        let saved_current = st.current[table].clone();
        st.current[table] = if st.requantized[table] {
            patch_any(&st.current[table], rows, q)
        } else {
            // Ingest-epoch tables requantize from the patched master
            // from scratch — deliberately a *different* code path from
            // the engine's in-place row patch, so every comparison
            // cross-checks the patch ≡ full-requantize invariant.
            AnyTable::Fused(st.masters[table].quantize_fused(q, self.nbits, self.sb))
        };
        let candidate = Arc::new(TableSet::new(st.current.clone()));
        let expected = {
            // lint:allow(raw_lock) — poison must propagate (see above).
            let mut snaps = self.snapshots.write().unwrap();
            let expected = snaps.len() as u64;
            snaps.push(candidate);
            expected
        };
        match apply() {
            Ok(v) => {
                assert_eq!(v, expected, "engine and oracle versions diverged");
                Ok(v)
            }
            Err(e) => {
                for (id, old) in &saved {
                    st.masters[table].row_mut(*id as usize).copy_from_slice(old);
                }
                st.current[table] = saved_current;
                // lint:allow(raw_lock) — poison must propagate (see above).
                let mut snaps = self.snapshots.write().unwrap();
                assert_eq!(snaps.len() as u64, expected + 1, "commit serialization broken");
                snaps.pop();
                Err(e)
            }
        }
    }

    /// Apply one whole-table online re-quantization through the engine
    /// while keeping the oracle in lockstep (same speculative-publish /
    /// rollback protocol as [`commit`]).
    ///
    /// `apply` performs the engine-side swap (a closure over
    /// [`requantize_to`] with a `chunk: None` plan entry for `table`).
    /// The oracle rebuilds its current image through
    /// [`budget::build_table`], the engine's only re-encoding path, so
    /// the two land on identical bytes: fused quantization is per-row,
    /// making the whole-table rebuild equal the concatenation of the
    /// engine's per-chunk rebuilds. Codebook targets are refused —
    /// their codebooks are trained per row-group, so a whole-table
    /// oracle image could not mirror a chunked engine's per-chunk
    /// codebooks. Identity re-quantizations are refused too: the engine
    /// would skip the swap without bumping the version, leaving nothing
    /// to commit.
    ///
    /// [`commit`]: VersionedOracle::commit
    /// [`requantize_to`]: crate::shard::ShardedEngine::requantize_to
    pub fn commit_requant<F>(
        &self,
        table: usize,
        format: FormatTag,
        q: &dyn Quantizer,
        apply: F,
    ) -> io::Result<u64>
    where
        F: FnOnce() -> io::Result<u64>,
    {
        assert!(
            !matches!(format, FormatTag::Codebook { .. }),
            "codebook targets are per-row-group; the whole-table oracle cannot mirror them"
        );
        // lint:allow(raw_lock) — poison must propagate (see commit).
        let mut st = self.state.lock().unwrap();
        assert!(table < st.current.len(), "requant of unknown table {table}");
        assert_ne!(
            FormatTag::of(&st.current[table]),
            format,
            "identity re-quantization: the engine skips the swap and never bumps the version"
        );
        let saved_current = st.current[table].clone();
        let saved_flag = st.requantized[table];
        st.current[table] = budget::build_table(&st.current[table], format, q);
        st.requantized[table] = true;
        let candidate = Arc::new(TableSet::new(st.current.clone()));
        let expected = {
            // lint:allow(raw_lock) — poison must propagate (see above).
            let mut snaps = self.snapshots.write().unwrap();
            let expected = snaps.len() as u64;
            snaps.push(candidate);
            expected
        };
        match apply() {
            Ok(v) => {
                assert_eq!(v, expected, "engine and oracle versions diverged");
                Ok(v)
            }
            Err(e) => {
                st.current[table] = saved_current;
                st.requantized[table] = saved_flag;
                // lint:allow(raw_lock) — poison must propagate (see above).
                let mut snaps = self.snapshots.write().unwrap();
                assert_eq!(snaps.len() as u64, expected + 1, "commit serialization broken");
                snaps.pop();
                Err(e)
            }
        }
    }

    /// Pooled lookup against the snapshot at `version` (panics if the
    /// version was never committed).
    pub fn pool_at(&self, version: u64, req: &Request) -> Vec<f32> {
        // lint:allow(raw_lock) — poison must propagate (see commit).
        let set = Arc::clone(&self.snapshots.read().unwrap()[version as usize]);
        let mut out = vec![0.0f32; set.feature_width()];
        for t in 0..set.num_tables() {
            if req.ids[t].is_empty() {
                continue;
            }
            let lo = set.offset_of(t);
            let hi = lo + set.dim_of(t);
            set.pool(t, &req.ids[t], &mut out[lo..hi]);
        }
        out
    }
}

/// Patch `(global_row, values)` pairs into a quantized image the way
/// the engine's update path does — per-row re-quantization for fused
/// formats, an FP32 splice for FP32, re-clustering for codebooks
/// (whole, unsplit tables only: the covering row-group is the table).
fn patch_any(t: &AnyTable, rows: &[(u32, Vec<f32>)], q: &dyn Quantizer) -> AnyTable {
    match t {
        AnyTable::F32(t) => {
            let dim = t.dim();
            let mut data = t.data().to_vec();
            for (id, vals) in rows {
                let i = *id as usize;
                data[i * dim..(i + 1) * dim].copy_from_slice(vals);
            }
            AnyTable::F32(EmbeddingTable::from_data(dim, data))
        }
        AnyTable::Fused(t) => {
            let mut fused = FusedTable::from_raw(
                t.rows(),
                t.dim(),
                t.nbits(),
                t.scale_bias_dtype(),
                t.data().to_vec(),
            );
            for (id, vals) in rows {
                let raw = quantize_row_fused(vals, q, t.nbits(), t.scale_bias_dtype());
                fused.patch_row(*id as usize, &raw);
            }
            AnyTable::Fused(fused)
        }
        AnyTable::Codebook(t) => {
            let mut data = t.dequantize();
            for (id, vals) in rows {
                data.row_mut(*id as usize).copy_from_slice(vals);
            }
            AnyTable::Codebook(data.quantize_codebook(t.kind(), t.scale_bias_dtype()))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::GreedyQuantizer;
    use crate::shard::{GroupAssignment, ShardConfig, ShardedEngine};

    fn masters(n: usize, rows: usize, dim: usize) -> Vec<EmbeddingTable> {
        (0..n).map(|t| EmbeddingTable::randn(rows, dim, 4300 + t as u64)).collect()
    }

    #[test]
    fn oracle_tracks_engine_versions_bit_exactly() {
        let q = GreedyQuantizer::default();
        let oracle = VersionedOracle::new(masters(2, 24, 4), &q, 4, ScaleBiasDtype::F16);
        let engine = ShardedEngine::start(
            oracle.quantized_set(),
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..ShardConfig::default() },
        );
        let req = Request { ids: vec![vec![0, 3, 23], vec![5, 5]] };
        assert_eq!(engine.lookup(&req), oracle.pool_at(1, &req), "version 1 agrees");

        let rows: Vec<(u32, Vec<f32>)> = vec![(3, vec![0.5; 4]), (17, vec![-1.25; 4])];
        let v = oracle
            .commit(0, &rows, &q, || engine.update_table(0, &rows, &q))
            .expect("commit succeeds");
        assert_eq!(v, 2);
        assert_eq!(oracle.latest_version(), 2);
        assert_eq!(engine.version(), 2);
        let req2 = Request { ids: vec![vec![3, 17], vec![1]] };
        assert_eq!(engine.lookup(&req2), oracle.pool_at(2, &req2), "version 2 agrees");
        // The old snapshot is still readable and still different.
        assert_ne!(oracle.pool_at(1, &req2), oracle.pool_at(2, &req2));
    }

    #[test]
    fn failed_commits_are_rolled_back() {
        let q = GreedyQuantizer::default();
        let oracle = VersionedOracle::new(masters(1, 16, 4), &q, 4, ScaleBiasDtype::F16);
        let engine = ShardedEngine::start(
            oracle.quantized_set(),
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..ShardConfig::default() },
        );
        let before = oracle.pool_at(1, &Request { ids: vec![vec![2]] });
        // A valid-looking batch whose apply fails mid-swap.
        let rows: Vec<(u32, Vec<f32>)> = vec![(2, vec![9.0; 4])];
        let err = oracle
            .commit(0, &rows, &q, || Err(io::Error::new(io::ErrorKind::Other, "injected")))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Other);
        assert_eq!(oracle.latest_version(), 1, "speculative snapshot retracted");
        assert_eq!(
            oracle.pool_at(1, &Request { ids: vec![vec![2]] }),
            before,
            "state rolled back"
        );
        // A malformed batch is rejected by the engine and leaves no trace.
        let bad: Vec<(u32, Vec<f32>)> = vec![(999, vec![1.0; 4])];
        assert!(oracle.commit(0, &bad, &q, || engine.update_table(0, &bad, &q)).is_err());
        assert_eq!(oracle.latest_version(), 1);
        // After all that, a real commit still lands cleanly at version 2.
        let v = oracle.commit(0, &rows, &q, || engine.update_table(0, &rows, &q)).unwrap();
        assert_eq!(v, 2);
        let req = Request { ids: vec![vec![2]] };
        assert_eq!(engine.lookup(&req), oracle.pool_at(2, &req));
    }

    #[test]
    fn requant_commits_mirror_the_engine_bit_exactly() {
        let int8 = FormatTag::Fused { nbits: 8, scale_bias: ScaleBiasDtype::F16 };
        let q = GreedyQuantizer::default();
        let oracle = VersionedOracle::new(masters(2, 24, 4), &q, 4, ScaleBiasDtype::F16);
        let engine = ShardedEngine::start(
            oracle.quantized_set(),
            &ShardConfig { num_shards: 2, small_table_rows: 0, ..ShardConfig::default() },
        );
        // Whole-table requant of a row-wise split table: the engine
        // rebuilds chunk by chunk, the oracle in one piece — per-row
        // fused quantization makes the bytes agree anyway.
        let plan = [GroupAssignment { table: 0, chunk: None, format: int8 }];
        let v = oracle
            .commit_requant(0, int8, &q, || engine.requantize_to(&plan, &q))
            .expect("requant commit succeeds");
        assert_eq!(v, 2);
        assert_eq!(engine.version(), 2);
        let req = Request { ids: vec![vec![0, 7, 23], vec![5]] };
        assert_eq!(engine.lookup(&req), oracle.pool_at(2, &req), "int8 epoch agrees");
        assert_ne!(oracle.pool_at(1, &req), oracle.pool_at(2, &req), "int8 differs from int4");

        // A row update on the drifted table keeps mirroring: the oracle
        // now patches its quantized image the way the engine does.
        let rows: Vec<(u32, Vec<f32>)> = vec![(7, vec![0.5; 4]), (12, vec![-2.0; 4])];
        let v = oracle.commit(0, &rows, &q, || engine.update_table(0, &rows, &q)).unwrap();
        assert_eq!(v, 3);
        let req2 = Request { ids: vec![vec![7, 12, 8], vec![1]] };
        assert_eq!(engine.lookup(&req2), oracle.pool_at(3, &req2), "post-drift update agrees");

        // A failed requant rolls back cleanly and leaves both sides at
        // the last committed version.
        let err = oracle
            .commit_requant(1, int8, &q, || Err(io::Error::new(io::ErrorKind::Other, "injected")))
            .unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::Other);
        assert_eq!(oracle.latest_version(), 3);
        assert_eq!(engine.lookup(&req2), oracle.pool_at(3, &req2), "rolled back");
    }
}
