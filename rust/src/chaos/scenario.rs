//! Scenario driver: seeded traffic + concurrent updaters + fault
//! injection, with invariants checked continuously.
//!
//! A scenario is a pure function of its [`ScenarioConfig`]: the traffic
//! stream, update payloads, and fault schedule all derive from
//! `config.seed`, and the returned [`ScenarioReport`] contains only
//! schedule-derived facts, so running the same config twice yields the
//! same report (the integration suite asserts exactly that).
//!
//! # Determinism under real concurrency
//!
//! Reader and updater threads are real OS threads racing the fault
//! injector, so *point* observations (which version a given read saw,
//! how many promote errors a corrupt window produced) are not
//! reproducible. The harness keeps its checks sound anyway:
//!
//! * **Window checks** — every checked read records the engine version
//!   before and after; the result must equal the oracle at *some single
//!   version in that window*. A result that matches no single version
//!   is a torn (mixed-version) or corrupt read and fails the run.
//! * **Epoch gating** — destructive fault windows (corrupt/truncated
//!   spill files) flip a shared epoch counter to odd before damaging
//!   bytes and back to even only after restoring them. Readers sample
//!   the epoch before and after each read and skip the comparison if it
//!   was odd or changed mid-read; the engine still *serves* (exercising
//!   its error paths), it just isn't held to bit-exactness while its
//!   disk tier is actively sabotaged. Only the main thread mutates the
//!   epoch, at tick boundaries, so which ticks are gated is a pure
//!   function of the schedule.
//! * **Disjoint-table updaters** — updater `u` only writes tables `t`
//!   with `t % updaters == u`, and applies its own batches in program
//!   order (retrying through fault windows until the commit lands).
//!   Cross-updater interleaving therefore commutes: the final table
//!   state and final version (`1 + update_batches + requant_commits`)
//!   are deterministic even though intermediate snapshots are not.
//! * **Requant storms** — [`FaultKind::RequantStorm`] drives online
//!   re-quantization commits from the main thread, in lockstep with
//!   the oracle ([`VersionedOracle::commit_requant`]), racing the
//!   updater threads and any background spill churn. Each commit flips
//!   one table's format (int4 ↔ int8) through the engine's MVCC swap,
//!   so the storm is *transparent*: readers stay held to bit-exactness
//!   through it — every result must still match the oracle at a single
//!   committed version. The flip sequence is a pure function of the
//!   schedule, so the final formats are deterministic too.

use std::fs;
use std::io;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use crate::data::trace::Request;
use crate::quant::GreedyQuantizer;
use crate::shard::{ShardConfig, ShardedEngine};
use crate::table::{EmbeddingTable, ScaleBiasDtype};
use crate::util::Rng;

use super::{DiurnalTraffic, VersionedOracle};

/// A fault the scenario driver can inject.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    /// Panic a shard worker mid-batch via an out-of-range id; the
    /// engine must zero the segment, count the panic, and keep serving.
    WorkerPanic,
    /// Spill everything, then flip a byte in every spill file. Promotes
    /// fail (checksum mismatch) until the heal restores the bytes.
    CorruptSpill,
    /// Spill everything, then truncate every spill file below its
    /// header. Promotes fail (short read) until the heal restores them.
    TruncateSpill,
    /// Delete the spill directory outright. Demotions fail and slices
    /// stay resident — serving and updates continue bit-exactly, just
    /// over budget — until the heal recreates the directory. Requires
    /// `budget_frac: None` (with a budget, background demotions would
    /// have written files whose deletion loses data permanently —
    /// demotes are write-once).
    SpillDirOutage,
    /// Stall every spill I/O worker for [`ScenarioConfig::wedge_ms`].
    /// Foreground reads resolve inline and stay bit-exact throughout.
    WedgeIo,
    /// Online re-quantization storm: across the fault window the main
    /// thread commits [`ScenarioConfig::requant_commits`] whole-table
    /// format flips (int4 ↔ int8) through the engine's `requantize_to`
    /// snapshot swap, in lockstep with the oracle — racing the updater
    /// threads and any spill churn. Transparent: readers are held to
    /// bit-exactness *through* the storm, and every commit bumps the
    /// version exactly once.
    RequantStorm,
}

/// Everything a scenario run derives from. See [`run_scenario`].
#[derive(Clone, Debug)]
pub struct ScenarioConfig {
    /// Master seed for traffic, update payloads, and reader streams.
    pub seed: u64,
    /// Number of embedding tables (all `rows × dim`).
    pub tables: usize,
    /// Rows per table.
    pub rows: usize,
    /// Embedding dimension.
    pub dim: usize,
    /// Worker shards.
    pub shards: usize,
    /// Main-loop ticks (the fault schedule is spread across these).
    pub ticks: usize,
    /// Mean requests per tick (diurnal envelope swings ±75%).
    pub base_batch: usize,
    /// Diurnal cycle length in ticks.
    pub diurnal_period: usize,
    /// Mean pooled ids per table per request.
    pub mean_pool: usize,
    /// Zipf skew of row popularity.
    pub zipf_alpha: f64,
    /// Resident budget as a fraction of logical table bytes; `None`
    /// runs un-budgeted (required by [`FaultKind::SpillDirOutage`]).
    pub budget_frac: Option<f64>,
    /// Spill directory; `None` creates (and removes) a unique temp dir.
    pub spill_dir: Option<PathBuf>,
    /// Concurrent updater threads (each owns the tables
    /// `t % updaters == u`; must be ≤ `tables`).
    pub updaters: usize,
    /// Total update batches across all updaters; the final version is
    /// `1 + update_batches`.
    pub update_batches: usize,
    /// Rows patched per update batch.
    pub update_rows: usize,
    /// Concurrent checking reader threads.
    pub readers: usize,
    /// Online re-quantization commits driven across the
    /// [`FaultKind::RequantStorm`] window (required > 0 iff the storm
    /// is scheduled). Each flips one table int4 ↔ int8; the final
    /// version is `1 + update_batches + requant_commits`.
    pub requant_commits: usize,
    /// Fault schedule, injected in order at evenly spread ticks.
    pub faults: Vec<FaultKind>,
    /// Stall length for [`FaultKind::WedgeIo`].
    pub wedge_ms: u64,
    /// Pin the engine's SLS kernel backend (`None` = resolve from the
    /// environment and CPU, like production). The oracle always pools
    /// through the process-default backend, so a pinned run is itself a
    /// cross-backend bit-exactness check: every window comparison holds
    /// the pinned engine to the oracle's results bit-for-bit.
    pub kernel_backend: Option<crate::sls::KernelBackend>,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            seed: 0xC0DE,
            tables: 3,
            rows: 512,
            dim: 8,
            shards: 4,
            ticks: 32,
            base_batch: 6,
            diurnal_period: 16,
            mean_pool: 4,
            zipf_alpha: 1.1,
            budget_frac: Some(0.5),
            spill_dir: None,
            updaters: 2,
            update_batches: 12,
            update_rows: 8,
            readers: 2,
            requant_commits: 0,
            faults: Vec::new(),
            wedge_ms: 50,
            kernel_backend: None,
        }
    }
}

/// What a scenario run observed. Every field is a pure function of the
/// [`ScenarioConfig`] — race-dependent observations are checked inline
/// (panicking the run on violation) rather than reported.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScenarioReport {
    /// Engine version after all updates and requant commits landed
    /// (`1 + update_batches + requant_commits`).
    pub final_version: u64,
    /// Update batches committed (== `update_batches`; every batch
    /// retries until it lands).
    pub committed_updates: u64,
    /// Online re-quantization commits landed (== `requant_commits`;
    /// every commit retries until it lands).
    pub requant_commits: u64,
    /// The derived fault schedule: `(start_tick, heal_tick, kind)`.
    pub schedule: Vec<(usize, usize, FaultKind)>,
    /// Main-loop requests compared bit-exactly against the oracle
    /// (requests served during gated fault windows are excluded).
    pub main_reads_checked: u64,
    /// Faults injected and healed, each followed by a verified probe.
    pub recoveries: usize,
    /// Final per-row sweep matched the oracle at `final_version`.
    pub bit_exact_final: bool,
    /// Resident bytes settled at or under the budget after the run
    /// (vacuously true without a budget).
    pub budget_ok: bool,
    /// `version()` never decreased and every shard's stats reported the
    /// final version at the end.
    pub version_monotone: bool,
}

/// Bytes restored on heal, keyed by path.
type SavedFiles = Vec<(PathBuf, Vec<u8>)>;

enum ActiveFault {
    /// Corrupt/truncated files to restore; the epoch is odd (gated).
    Damaged(SavedFiles),
    /// Spill directory deleted; nothing to restore but the directory.
    DirGone,
    /// Panic/wedge: transparent to correctness, heal is probe-only.
    Transparent,
}

/// Serial for unique per-process spill dirs (two runs of the same
/// config must not share one).
static DIR_SEQ: AtomicU64 = AtomicU64::new(0);

/// Run one scenario to completion, panicking on any invariant
/// violation and returning the deterministic [`ScenarioReport`].
pub fn run_scenario(cfg: &ScenarioConfig) -> ScenarioReport {
    assert!(cfg.tables > 0 && cfg.rows > 0 && cfg.dim > 0 && cfg.ticks > 0);
    if cfg.update_batches > 0 {
        assert!(
            cfg.updaters > 0 && cfg.updaters <= cfg.tables,
            "updaters must be in 1..=tables so each owns a disjoint, non-empty table set"
        );
    }
    let storms = cfg.faults.iter().filter(|f| **f == FaultKind::RequantStorm).count();
    assert!(storms <= 1, "at most one RequantStorm per run");
    assert_eq!(
        storms == 1,
        cfg.requant_commits > 0,
        "requant_commits must be > 0 exactly when a RequantStorm is scheduled"
    );
    if cfg.faults.contains(&FaultKind::SpillDirOutage) {
        assert!(
            cfg.budget_frac.is_none(),
            "SpillDirOutage needs budget_frac: None — background demotions under a budget \
             write spill files whose deletion would lose rows permanently"
        );
        assert!(
            !cfg.faults.iter().any(|f| matches!(
                f,
                FaultKind::CorruptSpill | FaultKind::TruncateSpill
            )),
            "SpillDirOutage cannot share a run with spill_all-based faults: deleting the \
             directory while slices live on disk is unrecoverable data loss, not a fault"
        );
    }

    // --- Build the world: masters, oracle, engine, spill dir. ---
    let q = GreedyQuantizer::default();
    let masters: Vec<EmbeddingTable> = (0..cfg.tables)
        .map(|t| EmbeddingTable::randn(cfg.rows, cfg.dim, cfg.seed ^ (0xA5A5 + t as u64)))
        .collect();
    let oracle = VersionedOracle::new(masters, &q, 4, ScaleBiasDtype::F16);
    let set = oracle.quantized_set();
    let table_bytes = set.size_bytes();
    let budget = cfg.budget_frac.map(|f| (table_bytes as f64 * f) as usize);
    let (dir, own_dir) = match &cfg.spill_dir {
        Some(d) => (d.clone(), false),
        None => {
            let d = std::env::temp_dir().join(format!(
                "emberq-chaos-{}-{}",
                std::process::id(),
                DIR_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            (d, true)
        }
    };
    fs::create_dir_all(&dir).expect("create spill dir");
    let engine = ShardedEngine::start(
        set,
        &ShardConfig {
            num_shards: cfg.shards,
            small_table_rows: 0,
            resident_budget: budget,
            spill_dir: Some(dir.clone()),
            spill_io_threads: 2,
            prefetch_window: 0,
            kernel_backend: cfg.kernel_backend,
            ..ShardConfig::default()
        },
    );
    let fw = engine.feature_width();

    // --- Derive the fault schedule: evenly spread, non-overlapping. ---
    let n = cfg.faults.len();
    let schedule: Vec<(usize, usize, FaultKind)> = cfg
        .faults
        .iter()
        .enumerate()
        .map(|(i, &f)| {
            let start = (i + 1) * cfg.ticks / (n + 1);
            let span = (cfg.ticks / (2 * n.max(1))).max(1);
            (start, (start + span).min(cfg.ticks - 1), f)
        })
        .collect();
    for w in schedule.windows(2) {
        assert!(w[0].1 < w[1].0, "fault windows overlap — use more ticks or fewer faults");
    }
    if let Some(last) = schedule.last() {
        assert!(last.1 < cfg.ticks, "last fault never heals — use more ticks");
    }

    // --- Precompute each updater's deterministic batch program. ---
    // Batch b belongs to updater `b % updaters`; updater u only touches
    // tables `t % updaters == u`, so cross-updater commits commute.
    let mut programs: Vec<Vec<(usize, Vec<(u32, Vec<f32>)>)>> = vec![Vec::new(); cfg.updaters];
    for u in 0..cfg.updaters {
        let own: Vec<usize> = (0..cfg.tables).filter(|t| t % cfg.updaters == u).collect();
        let mut rng = Rng::new(cfg.seed ^ (0x5EED + u as u64));
        for b in 0..cfg.update_batches {
            if b % cfg.updaters != u {
                continue;
            }
            let table = own[rng.below(own.len())];
            let rows = (0..cfg.update_rows)
                .map(|_| (rng.below(cfg.rows) as u32, rng.normal_vec(cfg.dim, 0.25)))
                .collect();
            programs[u].push((table, rows));
        }
    }

    let epoch = AtomicU64::new(0);
    let stop = AtomicBool::new(false);
    let committed = AtomicU64::new(0);
    let mut main_reads_checked = 0u64;
    let mut recoveries = 0usize;
    let mut version_monotone = true;
    let mut requant_done = 0usize;

    std::thread::scope(|s| {
        let updater_handles: Vec<_> = programs
            .iter()
            .enumerate()
            .map(|(u, program)| {
                let (engine, oracle, committed, q) = (&engine, &oracle, &committed, &q);
                s.spawn(move || {
                    for (table, rows) in program {
                        // Bounded retry budget instead of a wall-clock
                        // deadline: the retry *count* is identical on
                        // every run, so a wedged engine fails after the
                        // same number of attempts regardless of host
                        // speed (~30s at the nominal 2ms backoff).
                        let mut retries_left = 15_000u32;
                        loop {
                            let r = oracle.commit(*table, rows, q, || {
                                engine.update_table(*table, rows, q)
                            });
                            match r {
                                Ok(_) => {
                                    committed.fetch_add(1, Ordering::Relaxed);
                                    break;
                                }
                                Err(_) if retries_left > 0 => {
                                    retries_left -= 1;
                                    std::thread::sleep(Duration::from_millis(2));
                                }
                                Err(e) => {
                                    panic!("updater {u} wedged after retry budget; last error: {e}")
                                }
                            }
                        }
                    }
                })
            })
            .collect();

        let reader_handles: Vec<_> = (0..cfg.readers)
            .map(|r| {
                let (engine, oracle, epoch, stop) = (&engine, &oracle, &epoch, &stop);
                let mut rng = Rng::new(cfg.seed ^ (0xBEEF + r as u64));
                let (tables, rows, pool) = (cfg.tables, cfg.rows, cfg.mean_pool);
                s.spawn(move || {
                    while !stop.load(Ordering::Acquire) {
                        let e0 = epoch.load(Ordering::Acquire);
                        if e0 % 2 == 0 {
                            let req = Request {
                                ids: (0..tables)
                                    .map(|_| {
                                        (0..1 + rng.below(pool))
                                            .map(|_| rng.below(rows) as u32)
                                            .collect()
                                    })
                                    .collect(),
                            };
                            let v_pre = engine.version();
                            let got = engine.lookup(&req);
                            let v_post = engine.version();
                            if epoch.load(Ordering::Acquire) == e0 {
                                let ok =
                                    (v_pre..=v_post).any(|v| oracle.pool_at(v, &req) == got);
                                assert!(
                                    ok,
                                    "reader {r}: result matches no single version in \
                                     [{v_pre}, {v_post}] — torn or corrupt read: {req:?}"
                                );
                            }
                        }
                        std::thread::sleep(Duration::from_millis(1));
                    }
                })
            })
            .collect();

        // --- Main loop: traffic, faults, continuous checks. ---
        let mut traffic = DiurnalTraffic::new(
            cfg.seed ^ 0xD1A1,
            cfg.tables,
            cfg.rows,
            cfg.base_batch,
            cfg.diurnal_period,
            cfg.mean_pool,
            cfg.zipf_alpha,
        );
        let mut active: Option<ActiveFault> = None;
        let mut fault_idx = 0usize;
        let mut last_version = engine.version();
        // Requant-storm state: the heal tick of an active storm window
        // and each table's current code width (the engine starts
        // everything at int4/f16).
        let mut storm_until: Option<usize> = None;
        let mut requant_nbits: Vec<u32> = vec![4; cfg.tables];
        for tick in 0..cfg.ticks {
            if fault_idx > 0 && schedule[fault_idx - 1].1 == tick {
                if let Some(f) = active.take() {
                    heal(f, &engine, &oracle, &dir, &epoch, cfg);
                    recoveries += 1;
                    storm_until = None;
                }
            }
            if fault_idx < schedule.len() && schedule[fault_idx].0 == tick {
                assert!(active.is_none(), "fault injected while another is active");
                active = Some(inject(schedule[fault_idx].2, &engine, &dir, &epoch, cfg));
                if schedule[fault_idx].2 == FaultKind::RequantStorm {
                    storm_until = Some(schedule[fault_idx].1);
                }
                fault_idx += 1;
            }

            // Spread the storm's commits across its window so they race
            // update commits and spill churn on every tick of it; the
            // flip sequence (table `i % tables`, 4 ↔ 8) is schedule-
            // derived, so the final formats are deterministic.
            if let Some(heal_tick) = storm_until {
                if tick < heal_tick && requant_done < cfg.requant_commits {
                    let burst =
                        (cfg.requant_commits - requant_done).div_ceil(heal_tick - tick);
                    for _ in 0..burst {
                        let table = requant_done % cfg.tables;
                        let nbits = if requant_nbits[table] == 4 { 8 } else { 4 };
                        let format = crate::coordinator::catalog::FormatTag::Fused {
                            nbits,
                            scale_bias: ScaleBiasDtype::F16,
                        };
                        let plan = [crate::shard::GroupAssignment {
                            table,
                            chunk: None,
                            format,
                        }];
                        // Same bounded-retry discipline as the updaters.
                        let mut retries_left = 15_000u32;
                        loop {
                            let r = oracle.commit_requant(table, format, &q, || {
                                engine.requantize_to(&plan, &q)
                            });
                            match r {
                                Ok(_) => break,
                                Err(_) if retries_left > 0 => {
                                    retries_left -= 1;
                                    std::thread::sleep(Duration::from_millis(2));
                                }
                                Err(e) => panic!(
                                    "requant storm wedged after retry budget; last error: {e}"
                                ),
                            }
                        }
                        requant_nbits[table] = nbits;
                        requant_done += 1;
                    }
                }
            }

            let reqs = traffic.tick(tick);
            let gated = epoch.load(Ordering::Acquire) % 2 == 1;
            let mut out = vec![0.0f32; reqs.len() * fw];
            let v_pre = engine.version();
            engine.lookup_batch_into(&reqs, &mut out);
            let v_post = engine.version();
            version_monotone &= v_pre >= last_version && v_post >= v_pre;
            last_version = v_post;
            if !gated {
                for (i, req) in reqs.iter().enumerate() {
                    let got = &out[i * fw..(i + 1) * fw];
                    let ok = (v_pre..=v_post).any(|v| oracle.pool_at(v, req) == got);
                    assert!(
                        ok,
                        "tick {tick}, request {i}: result matches no single version in \
                         [{v_pre}, {v_post}] — torn or corrupt read"
                    );
                    main_reads_checked += 1;
                }
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        if let Some(f) = active.take() {
            heal(f, &engine, &oracle, &dir, &epoch, cfg);
            recoveries += 1;
        }

        for h in updater_handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
        stop.store(true, Ordering::Release);
        for h in reader_handles {
            if let Err(p) = h.join() {
                std::panic::resume_unwind(p);
            }
        }
    });

    // --- Final sweep: versions, bit-exactness, tiers, budget. ---
    let final_version = engine.version();
    assert_eq!(final_version, oracle.latest_version(), "engine and oracle diverged");
    assert_eq!(
        final_version,
        1 + cfg.update_batches as u64 + cfg.requant_commits as u64,
        "every update batch and requant commit must have landed exactly once"
    );
    assert_eq!(
        requant_done, cfg.requant_commits,
        "the storm window must fit every scheduled requant commit"
    );
    let stats = engine.shard_stats();
    version_monotone &= stats.iter().all(|st| st.version == final_version);

    let mut bit_exact_final = true;
    for id in 0..cfg.rows {
        let req = Request { ids: vec![vec![id as u32]; cfg.tables] };
        if engine.lookup(&req) != oracle.pool_at(final_version, &req) {
            bit_exact_final = false;
            break;
        }
    }

    // Tier accounting must reconcile at every instant; budget
    // enforcement is asynchronous, so give it a moment to settle.
    let resident = || engine.shard_bytes().iter().sum::<usize>();
    assert_eq!(
        resident() + engine.spilled_bytes(),
        engine.table_bytes(),
        "RAM + disk tiers must cover the logical bytes exactly"
    );
    let budget_ok = match budget {
        None => true,
        Some(b) => {
            // Bounded poll budget instead of a wall-clock deadline (same
            // rationale as the updater retry loop): ~10s at the nominal
            // 5ms poll, but the attempt count is host-independent.
            let mut polls_left = 2_000u32;
            loop {
                if resident() <= b {
                    break true;
                }
                if polls_left == 0 {
                    break false;
                }
                polls_left -= 1;
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    };

    drop(engine);
    if own_dir {
        let _ = fs::remove_dir_all(&dir);
    }

    ScenarioReport {
        final_version,
        committed_updates: committed.load(Ordering::Relaxed),
        requant_commits: requant_done as u64,
        schedule,
        main_reads_checked,
        recoveries,
        bit_exact_final,
        budget_ok,
        version_monotone,
    }
}

/// Inject one fault (main thread only). Returns what `heal` must undo.
fn inject(
    kind: FaultKind,
    engine: &ShardedEngine,
    dir: &std::path::Path,
    epoch: &AtomicU64,
    cfg: &ScenarioConfig,
) -> ActiveFault {
    match kind {
        FaultKind::WorkerPanic => {
            let before: u64 = engine.shard_stats().iter().map(|s| s.panics).sum();
            let mut ids = vec![vec![0u32]; cfg.tables];
            ids[0] = vec![cfg.rows as u32 * 4];
            let got = engine.lookup(&Request { ids });
            assert_eq!(&got[..cfg.dim], &vec![0.0f32; cfg.dim][..], "panicked segment zeroed");
            let after: u64 = engine.shard_stats().iter().map(|s| s.panics).sum();
            assert!(after > before, "worker panic must be counted");
            ActiveFault::Transparent
        }
        FaultKind::WedgeIo => {
            engine.wedge_spill_io(Duration::from_millis(cfg.wedge_ms), 8);
            ActiveFault::Transparent
        }
        FaultKind::RequantStorm => {
            // The storm itself is driven tick-by-tick from the main
            // loop (the commits must interleave with traffic and the
            // updaters); injection only opens the window. Transparent:
            // every commit is an atomic MVCC swap, so readers stay
            // checked throughout.
            ActiveFault::Transparent
        }
        FaultKind::CorruptSpill | FaultKind::TruncateSpill => {
            // Gate first so readers stop holding results to bit-
            // exactness, then damage the disk tier.
            epoch.fetch_add(1, Ordering::Release);
            engine.spill_all().expect("spill_all over a healthy dir");
            let mut saved = Vec::new();
            let mut paths: Vec<PathBuf> = fs::read_dir(dir)
                .expect("list spill dir")
                .filter_map(|e| e.ok())
                .map(|e| e.path())
                .filter(|p| p.extension().is_some_and(|x| x == "spill"))
                .collect();
            paths.sort();
            for p in paths {
                // Updates race us and may unlink files mid-walk; damage
                // only what we could save.
                let Ok(orig) = fs::read(&p) else { continue };
                let damaged = match kind {
                    FaultKind::CorruptSpill => {
                        let mut d = orig.clone();
                        let last = d.len() - 1;
                        d[last] ^= 0xFF;
                        d
                    }
                    _ => orig[..orig.len().min(20)].to_vec(),
                };
                if fs::write(&p, &damaged).is_ok() {
                    saved.push((p, orig));
                }
            }
            assert!(!saved.is_empty(), "nothing spilled — the fault would be a no-op");
            ActiveFault::Damaged(saved)
        }
        FaultKind::SpillDirOutage => {
            assert_eq!(engine.spilled_bytes(), 0, "outage must precede any demotion");
            fs::remove_dir_all(dir).expect("delete spill dir");
            let err = engine.spill_all().expect_err("demotion into a missing dir must fail");
            assert!(err.kind() == io::ErrorKind::NotFound || err.raw_os_error().is_some());
            // Over budget beats serving nothing: everything stayed
            // resident, so serving continues bit-exactly un-gated.
            assert_eq!(engine.spilled_bytes(), 0);
            ActiveFault::DirGone
        }
    }
}

/// Undo a fault (main thread only), then prove the engine recovered:
/// a full-table probe must match the oracle at some single version in
/// its read window.
fn heal(
    fault: ActiveFault,
    engine: &ShardedEngine,
    oracle: &VersionedOracle,
    dir: &std::path::Path,
    epoch: &AtomicU64,
    cfg: &ScenarioConfig,
) {
    match fault {
        ActiveFault::Transparent => {}
        ActiveFault::Damaged(saved) => {
            for (p, orig) in saved {
                // A committed update may have retired (unlinked) the
                // file since; restoring it would recreate a stale
                // orphan, so skip paths that are gone.
                if p.exists() {
                    fs::write(&p, &orig).expect("restore spill file");
                }
            }
            epoch.fetch_add(1, Ordering::Release);
        }
        ActiveFault::DirGone => {
            fs::create_dir_all(dir).expect("recreate spill dir");
            engine.spill_all().expect("demotion works again after the dir returns");
        }
    }
    let req = Request { ids: vec![(0..cfg.rows as u32).collect(); cfg.tables] };
    let v_pre = engine.version();
    let got = engine.lookup(&req);
    let v_post = engine.version();
    let ok = (v_pre..=v_post).any(|v| oracle.pool_at(v, &req) == got);
    assert!(ok, "post-heal probe is not bit-exact — the engine did not recover");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_free_scenario_is_deterministic_and_bit_exact() {
        let cfg = ScenarioConfig {
            seed: 0xFA_CE,
            tables: 2,
            rows: 64,
            dim: 4,
            shards: 2,
            ticks: 8,
            base_batch: 3,
            diurnal_period: 8,
            updaters: 1,
            update_batches: 4,
            update_rows: 4,
            readers: 1,
            ..ScenarioConfig::default()
        };
        let a = run_scenario(&cfg);
        assert_eq!(a.final_version, 5);
        assert_eq!(a.committed_updates, 4);
        assert!(a.bit_exact_final && a.budget_ok && a.version_monotone);
        assert!(a.main_reads_checked > 0, "an ungated run checks every main read");
        assert_eq!(a, run_scenario(&cfg), "same config, same report");
    }

    #[test]
    fn transparent_faults_never_gate_the_checks() {
        // Panic + wedge leave serving bit-exact, so every main-loop
        // read stays checked and recovery probes pass.
        let cfg = ScenarioConfig {
            seed: 0xB0_07,
            tables: 2,
            rows: 48,
            dim: 4,
            shards: 2,
            ticks: 12,
            base_batch: 3,
            diurnal_period: 6,
            updaters: 1,
            update_batches: 3,
            update_rows: 2,
            readers: 1,
            faults: vec![FaultKind::WorkerPanic, FaultKind::WedgeIo],
            wedge_ms: 10,
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&cfg);
        assert_eq!(r.recoveries, 2);
        assert_eq!(r.schedule.len(), 2);
        assert!(r.bit_exact_final && r.budget_ok && r.version_monotone);
        let ungated: u64 = r.main_reads_checked;
        assert!(ungated > 0);
    }

    #[test]
    fn requant_storm_keeps_reads_bit_exact_through_format_flips() {
        // Four whole-table flips (both tables up to int8, then back to
        // int4) race one updater and the spill churn of a 0.5 budget;
        // every read stays checked (the storm is transparent), and the
        // final version counts updates and requants exactly once each.
        let cfg = ScenarioConfig {
            seed: 0x4B17,
            tables: 2,
            rows: 64,
            dim: 4,
            shards: 2,
            ticks: 12,
            base_batch: 3,
            diurnal_period: 6,
            updaters: 1,
            update_batches: 3,
            update_rows: 4,
            readers: 1,
            requant_commits: 4,
            faults: vec![FaultKind::RequantStorm],
            ..ScenarioConfig::default()
        };
        let r = run_scenario(&cfg);
        assert_eq!(r.final_version, 1 + 3 + 4);
        assert_eq!(r.requant_commits, 4);
        assert_eq!(r.recoveries, 1);
        assert!(r.bit_exact_final && r.budget_ok && r.version_monotone);
        assert!(r.main_reads_checked > 0, "the storm never gates reads");
        assert_eq!(r, run_scenario(&cfg), "same config, same report");
    }

    #[test]
    #[should_panic(expected = "RequantStorm is scheduled")]
    fn requant_commits_without_a_storm_are_rejected() {
        run_scenario(&ScenarioConfig { requant_commits: 3, ..ScenarioConfig::default() });
    }

    #[test]
    #[should_panic(expected = "budget_frac: None")]
    fn dir_outage_under_a_budget_is_rejected() {
        run_scenario(&ScenarioConfig {
            faults: vec![FaultKind::SpillDirOutage],
            ..ScenarioConfig::default()
        });
    }
}
