//! Table 3 reproduction: model log loss and size after quantization, per
//! method and embedding dimension.
//!
//! ```bash
//! cargo bench --bench table3_model_loss [-- --quick]
//! ```

use emberq::data::{ClickBatch, CriteoConfig, SyntheticCriteo};
use emberq::eval::TableWriter;
use emberq::model::{Dlrm, DlrmConfig, QuantizedDlrm, Trainer, TrainerConfig};
use emberq::quant::{method_by_name, KmeansClsQuantizer, Method};
use emberq::table::{CodebookKind, ScaleBiasDtype};

fn train(dim: usize, steps: usize) -> (Dlrm, Vec<ClickBatch>) {
    let rows = 2_000;
    let dcfg = CriteoConfig { num_sparse: 4, rows_per_table: rows, ..Default::default() };
    let mcfg = DlrmConfig {
        num_tables: 4,
        rows_per_table: rows,
        dim,
        dense_dim: dcfg.dense_dim,
        hidden: vec![128, 128],
        seed: 0x7AB3 + dim as u64,
    };
    let mut model = Dlrm::new(mcfg);
    let mut data = SyntheticCriteo::train(dcfg.clone());
    Trainer::new(TrainerConfig { batch: 100, steps, log_every: steps, ..Default::default() })
        .train(&mut model, &mut data);
    let mut eval = SyntheticCriteo::eval(dcfg);
    let batches = (0..10).map(|_| eval.next_batch(500)).collect();
    (model, batches)
}

fn mean_loss(model_loss: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = model_loss.collect();
    v.iter().sum::<f64>() / v.len() as f64
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 150 } else { 600 };
    let dims = [8usize, 16, 32, 64, 128];
    use ScaleBiasDtype::{F16, F32};
    let rows: Vec<(&str, &str, u32, ScaleBiasDtype)> = vec![
        ("ASYM-8BITS", "ASYM", 8, F32),
        ("SYM", "SYM", 4, F32),
        ("GSS", "GSS", 4, F32),
        ("ASYM", "ASYM", 4, F32),
        ("HIST-APPRX", "HIST-APPRX", 4, F32),
        ("HIST-BRUTE", "HIST-BRUTE", 4, F32),
        ("ACIQ", "ACIQ", 4, F32),
        ("GREEDY", "GREEDY", 4, F32),
        ("GREEDY (FP16)", "GREEDY", 4, F16),
        ("KMEANS (FP16)", "KMEANS", 4, F16),
    ];

    let mut tw = TableWriter::new(
        std::iter::once("method".to_string())
            .chain(dims.iter().flat_map(|d| [format!("d={d} loss"), format!("d={d} size")]))
            .collect::<Vec<_>>(),
    );
    let mut fp32_row = vec!["FP32 (no quant)".to_string()];
    let mut cells: Vec<Vec<String>> = vec![Vec::new(); rows.len()];

    for &dim in &dims {
        eprintln!("training d={dim}...");
        let (model, batches) = train(dim, steps);
        let fp32 = mean_loss(batches.iter().map(|b| model.eval_logloss(b)));
        let bytes = model.tables_bytes();
        fp32_row.push(format!("{fp32:.5}"));
        fp32_row.push(format!("{:.1}MB", bytes as f64 / 1e6));
        for (mi, (label, name, nbits, sb)) in rows.iter().enumerate() {
            let method = method_by_name(name).unwrap();
            let q = match &method {
                Method::Uniform(u) => QuantizedDlrm::from_uniform(&model, u.as_ref(), *nbits, *sb),
                Method::Kmeans(_) => {
                    QuantizedDlrm::from_codebook(&model, CodebookKind::Rowwise, *sb)
                }
                Method::KmeansCls(_) => {
                    let budget = 2_000 * sb.tail_bytes();
                    let k = KmeansClsQuantizer::k_for_budget(2_000, budget).min(2_000);
                    QuantizedDlrm::from_codebook(&model, CodebookKind::TwoTier { k }, *sb)
                }
            };
            let loss = mean_loss(batches.iter().map(|b| q.eval_logloss(b)));
            let ratio = 100.0 * q.tables_bytes() as f64 / bytes as f64;
            cells[mi].push(format!("{loss:.5}"));
            cells[mi].push(format!("{ratio:.2}%"));
            eprintln!("  {label}: loss {loss:.5} size {ratio:.2}%");
        }
    }
    tw.row(fp32_row);
    for (mi, (label, _, _, _)) in rows.iter().enumerate() {
        let mut row = vec![label.to_string()];
        row.extend(cells[mi].clone());
        tw.row(row);
    }
    println!("\nTable 3 — model log loss and size after quantization:\n{}", tw.render());
    println!(
        "Paper shape: GREEDY the lowest-loss 4-bit uniform method at every d;\n\
         KMEANS matches FP32 loss; sizes match the closed-form ratios\n\
         (d=128 GREEDY(FP16): 13.28%)."
    );
}
