//! Figure 1 bench: normalized ℓ2 loss of 4-bit quantization vs embedding
//! dimension (10-row N(0,1) table), every method including the
//! GREEDY (opt) variant. HIST-BRUTE is O(b³) per row — at d ≥ 4096 it
//! dominates the runtime, so the sweep caps it unless --full is passed.
//!
//! ```bash
//! cargo bench --bench fig1_l2_vs_dim [-- --full]
//! ```

use emberq::eval::{normalized_l2_method, JsonWriter, TableWriter};
use emberq::quant::method_by_name;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let dims: Vec<usize> = (4..=13).map(|p| 1 << p).collect();
    let methods = [
        "TABLE",
        "ASYM",
        "GSS",
        "ACIQ",
        "HIST-APPRX",
        "HIST-BRUTE",
        "GREEDY",
        "GREEDY-OPT",
    ];
    let brute_cap = if full { usize::MAX } else { 2048 };

    let mut tw = TableWriter::new(
        std::iter::once("method".to_string())
            .chain(dims.iter().map(|d| format!("d={d}")))
            .collect::<Vec<_>>(),
    );
    let mut series: Vec<(String, Vec<f64>)> = Vec::new();
    for name in methods {
        let method = method_by_name(name).unwrap();
        let mut row = vec![name.to_string()];
        let mut vals = Vec::new();
        for &d in &dims {
            if name == "HIST-BRUTE" && d > brute_cap {
                row.push("-".into());
                vals.push(f64::NAN);
                continue;
            }
            let table = EmbeddingTable::randn(10, d, 0xF16);
            let l2 = normalized_l2_method(&table, &method, 4, ScaleBiasDtype::F32);
            row.push(format!("{l2:.5}"));
            vals.push(l2);
        }
        eprintln!("done {name}");
        tw.row(row);
        series.push((name.to_string(), vals));
    }
    println!("\nFigure 1 — normalized l2 vs dimension (10×d N(0,1)):\n{}", tw.render());

    // Machine-readable series for plotting.
    let mut j = JsonWriter::new();
    j.num_array("dims", &dims.iter().map(|&d| d as f64).collect::<Vec<_>>());
    for (name, vals) in &series {
        j.num_array(name, vals);
    }
    println!("JSON: {}", j.finish());

    // Shape assertions from the paper (soft — print PASS/FAIL).
    let get = |m: &str| &series.iter().find(|(n, _)| n == m).unwrap().1;
    let asym = get("ASYM");
    let gss = get("GSS");
    let greedy = get("GREEDY");
    let last = dims.len() - 1; // d=8192
    let d32 = 1; // dims[1] = 32
    let d64 = 2; // dims[2] = 64
    let checks = [
        // At d=64 GSS-vs-ASYM is within noise on a 10-row draw; the
        // separation the paper plots is clear at d=32.
        ("GSS worse than ASYM at d=32", gss[d32] > asym[d32]),
        ("GSS beats ASYM at d=8192", gss[last] < asym[last]),
        ("GREEDY best uniform at d=64", greedy[d64] < asym[d64] && greedy[d64] < gss[d64]),
        (
            "TABLE worst at d=64",
            get("TABLE")[d64] >= asym[d64],
        ),
    ];
    for (desc, ok) in checks {
        println!("{} {desc}", if ok { "PASS" } else { "FAIL" });
    }
}
