//! Shard-scaling benchmark: throughput of the row-wise sharded engine vs
//! the single-threaded INT4 SLS baseline, on the Table 1 workload shape
//! (large uniform-random pooled lookups over one big fused table).
//!
//! The baseline is the raw `sls_fused` kernel on one core — the exact
//! Table 1 INT4 measurement. The engine runs the same 200k pooled rows
//! as a 2000-request batch split across N shards. Target: ≥2× at 4
//! shards.
//!
//! ```bash
//! cargo bench --bench shard_scaling            # full (1M rows)
//! cargo bench --bench shard_scaling -- --quick # small + fast
//! ```

use emberq::coordinator::TableSet;
use emberq::data::trace::Request;
use emberq::eval::TableWriter;
use emberq::quant::AsymQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::sls::{sls_fused, SlsArgs};
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::bench::measure;
use emberq::util::Rng;

const DIM: usize = 128;
const SEGMENTS: usize = 2_000;
const POOL: usize = 100;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let rows = if quick { 200_000 } else { 1_000_000 };
    let (warm, reps) = if quick { (0, 3) } else { (1, 5) };
    let lookups = SEGMENTS * POOL;

    let fp32 = EmbeddingTable::randn_sigma(rows, DIM, 0.1, 0x51AD);
    let fused = fp32.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16);
    drop(fp32);
    let mut rng = Rng::new(0x51AE);
    let indices: Vec<u32> = (0..lookups).map(|_| rng.below(rows) as u32).collect();
    let lengths = vec![POOL as u32; SEGMENTS];

    // Single-threaded Table 1 baseline: the raw INT4 SLS kernel.
    let args = SlsArgs::new(&indices, &lengths, rows).unwrap();
    let mut sink = vec![0.0f32; SEGMENTS * DIM];
    let base = measure(warm, reps, || {
        sls_fused(&fused, &args, &mut sink);
        sink[0]
    });
    let base_gsums = (lookups * DIM) as f64 / base.secs() / 1e9;
    println!(
        "single-thread INT4 SLS baseline: {base_gsums:.3} GSums/s \
         ({rows} rows, d={DIM}, {lookups} pooled rows / {SEGMENTS} segments)"
    );

    // The same pooled work as a batch of requests through the engine.
    let set = TableSet::new(vec![AnyTable::Fused(fused.clone())]);
    let reqs: Vec<Request> = indices
        .chunks(POOL)
        .map(|c| Request { ids: vec![c.to_vec()] })
        .collect();
    let mut out = vec![0.0f32; SEGMENTS * DIM];
    let mut tw = TableWriter::new(vec!["shards", "GSums/s", "speedup vs 1-thread"]);
    for shards in [1usize, 2, 4, 8] {
        let engine = ShardedEngine::start(
            &set,
            &ShardConfig { num_shards: shards, small_table_rows: 0, ..Default::default() },
        );
        let m = measure(warm, reps, || {
            engine.lookup_batch_into(&reqs, &mut out);
            out[0]
        });
        let gsums = (lookups * DIM) as f64 / m.secs() / 1e9;
        tw.row(vec![
            shards.to_string(),
            format!("{gsums:.3}"),
            format!("{:.2}x", gsums / base_gsums),
        ]);
        eprintln!("shards={shards}: {gsums:.3} GSums/s ({:.2}x)", gsums / base_gsums);
    }
    println!(
        "\nShard scaling — INT4 SLS, Table 1 workload as a {SEGMENTS}-request batch:\n{}",
        tw.render()
    );
    println!("Paper-deployment check: >=2x at 4 shards over the single-threaded INT4 baseline.");
}
