//! Shard-scaling benchmark: throughput of the row-wise sharded engine vs
//! the single-threaded INT4 SLS baseline, on the Table 1 workload shape
//! (large uniform-random pooled lookups over one big fused table).
//!
//! The baseline is the raw `sls_fused` kernel on one core — the exact
//! Table 1 INT4 measurement. The engine runs the same pooled rows as a
//! batch split across N shards (slice-resident: each engine consumes its
//! own copy of the set). Per shard count it reports throughput, speedup,
//! and per-shard service-latency percentiles (p50/p95/p99) so skew is
//! visible, plus one machine-readable JSON line per configuration for
//! the CI bench artifact.
//!
//! Target: ≥2× at 4 shards.
//!
//! `--skewed` switches to the adaptive-load workload: many *whole*
//! fused tables with Zipf-distributed table popularity (hot tables
//! dominate, the skew real recommender traffic shows), measured with
//! static placement vs. work stealing + runtime re-replication. It
//! reports per-batch p50/p99 latency, steal counts, and rebalance
//! counters per arm, and asserts the two arms agree bit-for-bit.
//!
//! `--spill` switches to the tiered-storage workload: whole fused
//! tables with Zipf popularity served under a `--resident-budget`-style
//! byte cap (the cold tail lives on disk and promotes on touch),
//! measured against an unlimited-budget engine on the same requests. It
//! reports per-batch p50/p99 per arm plus promotion/demotion/spill-read
//! counters, and asserts the two arms agree bit-for-bit.
//!
//! `--update-churn` measures the MVCC snapshot-swap path: the same
//! batched reads with and without a background updater committing
//! `update_table` batches throughout the run. It reports read p50/p99
//! per arm (the cost of concurrent version swaps), the committed batch
//! count and final version, and asserts the churned engine's at-rest
//! state is bit-identical to requantizing the masters with the same
//! update program applied.
//!
//! `--saturate` drives an open-loop arrival-rate curve at the live TCP
//! fronts (reactor arms plus one blocking-front comparison arm): a
//! closed-loop probe against an unarmed server estimates capacity, then
//! each arm offers a fixed multiple of it on a precomputed schedule and
//! reports admitted vs shed plus the p50/p99 of *admitted* requests.
//! Past the knee the shed fraction must rise while admitted p99 stays
//! bounded — graceful degradation under overload, asserted — and every
//! served reply is checked bit-exactly against the engine's own answer.
//!
//! `--simd` measures the kernel-backend dispatch itself: the same
//! pooled workload per row format (FP32, INT4, INT8, codebook) timed on
//! the scalar oracle and on the best backend this CPU detects, p50/p99
//! per arm plus the speedup, with `to_bits` equality asserted between
//! the arms before anything is timed. On a CPU with no SIMD the arms
//! coincide (speedup ~1.0) and the JSON says `"backend": "scalar"`.
//!
//! `--mixed` measures heat-adaptive mixed precision against the paper's
//! uniform int4 at the *same* total byte budget: Zipf whole-table
//! traffic warms the heat window, one `requantize_once` pass upgrades
//! hot tables (int8) and downgrades the cold tail (shared codebooks),
//! and the arms report batch p50/p99, heat-weighted normalized L2 vs
//! the FP32 masters, and a synthetic ranking AUC. The adaptive arm must
//! be strictly below uniform int4 on heat-weighted error (asserted) —
//! the accuracy the budget buys back at equal bytes.
//!
//! ```bash
//! cargo bench --bench shard_scaling            # full (1M rows)
//! cargo bench --bench shard_scaling -- --quick # small + fast
//! cargo bench --bench shard_scaling -- --tiny  # CI smoke budget
//! cargo bench --bench shard_scaling -- --tiny --skewed  # adaptive arms
//! cargo bench --bench shard_scaling -- --tiny --spill   # tiered arms
//! cargo bench --bench shard_scaling -- --tiny --spill-async  # sync vs async I/O
//! cargo bench --bench shard_scaling -- --tiny --update-churn # live-update arms
//! cargo bench --bench shard_scaling -- --tiny --simd    # scalar vs SIMD kernels
//! cargo bench --bench shard_scaling -- --tiny --mixed   # mixed-precision arms
//! cargo bench --bench shard_scaling -- --tiny --saturate # admission-control curve
//! ```
//!
//! `--spill-async` isolates the async spill I/O engine: row-wise
//! chunked tables under a byte budget, with a `spill_all` storm before
//! every measured pass so each batch pays promote stalls. The `sync`
//! arm runs spill I/O inline (`spill_io_threads: 0` — streaming and
//! off-lock, but no overlap); the `async` arm uses the background pool
//! plus prefetching. Reported per arm: batch p50/p99 (the promote-stall
//! distribution) and promotion/prefetch/stream counters, bit-exactness
//! asserted across arms.

use std::net::SocketAddr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use emberq::coordinator::{
    AdmissionSnapshot, EmbeddingServer, LatencyHistogram, ReactorFront, ServerConfig, ShardStats,
    TableSet, TcpClient, TcpFront,
};
use emberq::data::trace::Request;
use emberq::eval::{roc_auc, JsonWriter, TableWriter};
use emberq::quant::{AsymQuantizer, GreedyQuantizer};
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::sls::{backend, sls_fused, KernelBackend, SlsArgs, SlsTable};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};
use emberq::util::bench::measure;
use emberq::util::{Rng, Zipf};

const DIM: usize = 128;
const POOL: usize = 100;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let tiny = std::env::args().any(|a| a == "--tiny");
    if std::env::args().any(|a| a == "--saturate") {
        run_saturate(tiny, quick);
        return;
    }
    if std::env::args().any(|a| a == "--simd") {
        run_simd(tiny, quick);
        return;
    }
    if std::env::args().any(|a| a == "--mixed") {
        run_mixed(tiny, quick);
        return;
    }
    if std::env::args().any(|a| a == "--update-churn") {
        run_update_churn(tiny, quick);
        return;
    }
    if std::env::args().any(|a| a == "--spill-async") {
        run_spill_async(tiny, quick);
        return;
    }
    if std::env::args().any(|a| a == "--spill") {
        run_spill(tiny, quick);
        return;
    }
    if std::env::args().any(|a| a == "--skewed") {
        run_skewed(tiny, quick);
        return;
    }
    let (rows, segments, warm, reps) = if tiny {
        (50_000, 200, 0, 1) // CI smoke: compile + one honest pass
    } else if quick {
        (200_000, 2_000, 0, 3)
    } else {
        (1_000_000, 2_000, 1, 5)
    };
    let lookups = segments * POOL;

    let fp32 = EmbeddingTable::randn_sigma(rows, DIM, 0.1, 0x51AD);
    let fused = fp32.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16);
    drop(fp32);
    let mut rng = Rng::new(0x51AE);
    let indices: Vec<u32> = (0..lookups).map(|_| rng.below(rows) as u32).collect();
    let lengths = vec![POOL as u32; segments];

    // Single-threaded Table 1 baseline: the raw INT4 SLS kernel.
    let args = SlsArgs::new(&indices, &lengths, rows).unwrap();
    let mut sink = vec![0.0f32; segments * DIM];
    let base = measure(warm, reps, || {
        sls_fused(&fused, &args, &mut sink);
        sink[0]
    });
    let base_gsums = (lookups * DIM) as f64 / base.secs() / 1e9;
    println!(
        "single-thread INT4 SLS baseline: {base_gsums:.3} GSums/s \
         ({rows} rows, d={DIM}, {lookups} pooled rows / {segments} segments)"
    );

    // The same pooled work as a batch of requests through the engine.
    let reqs: Vec<Request> = indices
        .chunks(POOL)
        .map(|c| Request { ids: vec![c.to_vec()] })
        .collect();
    let mut out = vec![0.0f32; segments * DIM];
    let mut tw = TableWriter::new(vec![
        "shards",
        "GSums/s",
        "speedup vs 1-thread",
        "per-shard p50/p95/p99 (max over shards)",
    ]);
    for shards in [1usize, 2, 4, 8] {
        // Each engine consumes its own set (slice-resident ownership).
        let set = TableSet::new(vec![AnyTable::Fused(fused.clone())]);
        let engine = ShardedEngine::start(
            set,
            &ShardConfig { num_shards: shards, small_table_rows: 0, ..Default::default() },
        );
        // Warm outside `measure` and snapshot, so the per-shard latency
        // percentiles cover only the timed repetitions (cold-cache
        // warmup would otherwise dominate p99 at these sample counts).
        for _ in 0..warm {
            engine.lookup_batch_into(&reqs, &mut out);
        }
        let before = engine.shard_stats();
        let m = measure(0, reps, || {
            engine.lookup_batch_into(&reqs, &mut out);
            out[0]
        });
        let gsums = (lookups * DIM) as f64 / m.secs() / 1e9;
        let stats: Vec<ShardStats> = engine
            .shard_stats()
            .iter()
            .zip(&before)
            .map(|(a, b)| a.since(b))
            .collect();
        let p50s: Vec<f64> = stats
            .iter()
            .map(|s| s.latency.quantile(0.50).as_nanos() as f64)
            .collect();
        let p95s: Vec<f64> = stats
            .iter()
            .map(|s| s.latency.quantile(0.95).as_nanos() as f64)
            .collect();
        let p99s: Vec<f64> = stats
            .iter()
            .map(|s| s.latency.quantile(0.99).as_nanos() as f64)
            .collect();
        let worst = |v: &[f64]| v.iter().fold(0.0f64, |a, &b| a.max(b)) / 1e6;
        tw.row(vec![
            shards.to_string(),
            format!("{gsums:.3}"),
            format!("{:.2}x", gsums / base_gsums),
            format!("{:.2}/{:.2}/{:.2} ms", worst(&p50s), worst(&p95s), worst(&p99s)),
        ]);
        eprintln!("shards={shards}: {gsums:.3} GSums/s ({:.2}x)", gsums / base_gsums);
        // Machine-readable line for the CI artifact (one JSON object per
        // shard count; `grep '^{'` extracts them).
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling")
            .num_field("shards", shards as f64)
            .num_field("rows", rows as f64)
            .num_field("segments", segments as f64)
            .num_field("baseline_gsums_per_s", base_gsums)
            .num_field("gsums_per_s", gsums)
            .num_field("speedup", gsums / base_gsums)
            .num_array("per_shard_p50_ns", &p50s)
            .num_array("per_shard_p95_ns", &p95s)
            .num_array("per_shard_p99_ns", &p99s);
        println!("{}", jw.finish());
    }
    println!(
        "\nShard scaling — INT4 SLS, Table 1 workload as a {segments}-request batch:\n{}",
        tw.render()
    );
    println!("Paper-deployment check: >=2x at 4 shards over the single-threaded INT4 baseline.");
}

/// Saturation mode: the admission-control curve, measured open-loop at
/// the live TCP fronts.
///
/// A closed-loop probe against an *unarmed* server (no inflight cap, no
/// SLO — the probe that calibrates admission must not be shed by it)
/// estimates capacity; the SLO and inflight cap for the measured server
/// derive from that estimate, so the bench is self-scaling across
/// machines. Each ladder arm then offers `multiple × capacity` on a
/// precomputed arrival schedule: requests are *due* at fixed instants
/// regardless of how the server is coping (open loop — the regime where
/// an unprotected server's queue grows without bound), a late sender
/// fires immediately, and admitted latency is measured from the
/// scheduled arrival so queueing delay is charged honestly.
///
/// Sub-capacity arms should sail through; past the knee the shed
/// fraction must rise (asserted) while the p99 of *admitted* requests
/// stays bounded (asserted) — load is refused at the door, not absorbed
/// into an ever-deeper queue. Every served reply is compared bit-exactly
/// against the engine's direct answer, and client-observed replies must
/// conserve: served + shed == offered.
fn run_saturate(tiny: bool, quick: bool) {
    let (rows, conns, budget, multiples): (usize, usize, usize, &[f64]) = if tiny {
        (4_000, 8, 1_200, &[0.5, 3.0])
    } else if quick {
        (10_000, 12, 4_000, &[0.5, 1.5, 3.0])
    } else {
        (40_000, 16, 12_000, &[0.5, 1.0, 2.0, 4.0])
    };
    // Heavy enough per-lookup work (4 tables × POOL rows × d=128) that
    // server-side service dominates the localhost round trip — otherwise
    // an offered rate derived from a closed-loop probe would not
    // translate into server-side overload.
    let num_tables = 4usize;
    let max_inflight = (conns / 2).max(2);
    let mk_tables = || -> Vec<AnyTable> {
        (0..num_tables)
            .map(|t| {
                let fp32 = EmbeddingTable::randn_sigma(rows, DIM, 0.1, 0x5A70 + t as u64);
                AnyTable::Fused(fp32.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16))
            })
            .collect()
    };

    // A fixed request pool, cycled by arrival index, so every served
    // reply has a precomputed oracle answer to match bit-for-bit.
    let mut rng = Rng::new(0x5A7A);
    let pool: Vec<Request> = (0..64)
        .map(|_| Request {
            ids: (0..num_tables)
                .map(|_| (0..POOL).map(|_| rng.below(rows) as u32).collect())
                .collect(),
        })
        .collect();

    // Closed-loop capacity probe (unarmed server, few conns).
    let probe_server = Arc::new(EmbeddingServer::start(
        TableSet::new(mk_tables()),
        ServerConfig { num_shards: 2, ..Default::default() },
    ));
    let probe_front =
        ReactorFront::start(Arc::clone(&probe_server), "127.0.0.1:0").expect("probe front");
    let probe_secs = if tiny { 0.15 } else { 0.4 };
    let probe_conns = conns.min(4);
    let t0 = Instant::now();
    let done: usize = std::thread::scope(|sc| {
        let handles: Vec<_> = (0..probe_conns)
            .map(|c| {
                let pool = &pool;
                let addr = probe_front.addr();
                sc.spawn(move || {
                    let mut client = TcpClient::connect(addr).expect("probe connect");
                    let mut n = 0usize;
                    while t0.elapsed().as_secs_f64() < probe_secs {
                        client.lookup(&pool[(c + n) % pool.len()].ids).expect("probe lookup");
                        n += 1;
                    }
                    n
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("probe thread")).sum()
    });
    let capacity = done as f64 / t0.elapsed().as_secs_f64();
    drop(probe_front);
    drop(probe_server);
    // SLO: a few multiples of the unloaded mean — tight enough that an
    // unbounded queue would blow it, loose enough that healthy jitter
    // does not.
    let mean_ms = probe_conns as f64 / capacity * 1e3;
    let slo_ms = (mean_ms * 4.0).ceil().clamp(1.0, 50.0) as u64;

    // The measured server: same tables, admission armed.
    let server = Arc::new(EmbeddingServer::start(
        TableSet::new(mk_tables()),
        ServerConfig { num_shards: 2, max_inflight, slo_ms, ..Default::default() },
    ));
    let oracle: Vec<Vec<f32>> = pool.iter().map(|r| server.lookup(r)).collect();
    let reactor = ReactorFront::start(Arc::clone(&server), "127.0.0.1:0").expect("reactor front");
    let blocking = TcpFront::start(Arc::clone(&server), "127.0.0.1:0").expect("blocking front");
    println!(
        "saturation workload: {num_tables} fused INT4 tables × {rows} rows × d={DIM}, \
         {POOL} pooled rows per table per lookup; capacity ≈ {capacity:.0} req/s \
         (closed loop, {probe_conns} conns); slo {slo_ms} ms, max-inflight {max_inflight}; \
         {conns} open-loop conns × {budget} requests per arm"
    );

    struct Arm {
        served: usize,
        shed: usize,
        p50_ms: f64,
        p99_ms: f64,
        achieved: f64,
        snap: AdmissionSnapshot,
    }
    let run_arm = |addr: SocketAddr, rate: f64, n: usize| -> Arm {
        let before = server.admission().snapshot();
        let next = AtomicUsize::new(0);
        let start = Instant::now();
        let (mut lats, mut shed) = (Vec::new(), 0usize);
        std::thread::scope(|sc| {
            let handles: Vec<_> = (0..conns)
                .map(|_| {
                    let (next, pool, oracle) = (&next, &pool, &oracle);
                    sc.spawn(move || {
                        let mut client = TcpClient::connect(addr).expect("arm connect");
                        let mut lats = Vec::new();
                        let mut shed = 0usize;
                        loop {
                            let i = next.fetch_add(1, Ordering::Relaxed);
                            if i >= n {
                                break;
                            }
                            let due = start + Duration::from_secs_f64(i as f64 / rate);
                            let now = Instant::now();
                            if due > now {
                                std::thread::sleep(due - now);
                            }
                            match client.lookup(&pool[i % pool.len()].ids) {
                                Ok(got) => {
                                    assert_eq!(
                                        got,
                                        oracle[i % pool.len()],
                                        "served reply diverged from the oracle"
                                    );
                                    // From the *scheduled* arrival: lateness
                                    // and queueing are charged to the server.
                                    lats.push(due.elapsed().as_secs_f64() * 1e3);
                                }
                                Err(e) => {
                                    let msg = e.to_string();
                                    assert!(
                                        msg.starts_with("shed: "),
                                        "unexpected error under load: {msg}"
                                    );
                                    shed += 1;
                                }
                            }
                        }
                        (lats, shed)
                    })
                })
                .collect();
            for h in handles {
                let (l, s) = h.join().expect("arm thread");
                lats.extend(l);
                shed += s;
            }
        });
        let wall = start.elapsed().as_secs_f64();
        lats.sort_by(f64::total_cmp);
        let pctl = |q: f64| -> f64 {
            if lats.is_empty() {
                0.0
            } else {
                lats[((lats.len() - 1) as f64 * q).round() as usize]
            }
        };
        let after = server.admission().snapshot();
        Arm {
            served: lats.len(),
            shed,
            p50_ms: pctl(0.50),
            p99_ms: pctl(0.99),
            achieved: n as f64 / wall,
            snap: AdmissionSnapshot {
                admitted: after.admitted - before.admitted,
                shed_inflight: after.shed_inflight - before.shed_inflight,
                shed_slo: after.shed_slo - before.shed_slo,
                shed_deadline: after.shed_deadline - before.shed_deadline,
                refused_conns: after.refused_conns - before.refused_conns,
                idle_closed: after.idle_closed - before.idle_closed,
                inflight: after.inflight,
            },
        }
    };

    let mut tw = TableWriter::new(vec![
        "front",
        "rate (x capacity)",
        "offered/s",
        "served",
        "shed",
        "admitted p50/p99 (ms)",
    ]);
    let emit = |tw: &mut TableWriter, front: &str, m: f64, rate: f64, arm: &Arm| {
        assert_eq!(arm.served + arm.shed, budget, "replies must conserve: served + shed == offered");
        assert!(arm.served > 0, "{front} at {m}x: admitted traffic must keep flowing");
        assert!(
            arm.p99_ms < 1_000.0,
            "{front} at {m}x: admitted p99 {:.1} ms is unbounded-queue territory",
            arm.p99_ms
        );
        tw.row(vec![
            front.to_string(),
            format!("{m:.1}x"),
            format!("{:.0}", arm.achieved),
            arm.served.to_string(),
            arm.shed.to_string(),
            format!("{:.3}/{:.3}", arm.p50_ms, arm.p99_ms),
        ]);
        eprintln!(
            "{front} {m:.1}x: offered {:.0}/s, served {}, shed {} \
             (inflight {}, slo {}, deadline {}), admitted p50={:.3} ms p99={:.3} ms",
            arm.achieved,
            arm.served,
            arm.shed,
            arm.snap.shed_inflight,
            arm.snap.shed_slo,
            arm.snap.shed_deadline,
            arm.p50_ms,
            arm.p99_ms
        );
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling_saturate")
            .str_field("front", front)
            .num_field("rate_multiple", m)
            .num_field("capacity_per_s", capacity)
            .num_field("target_rate_per_s", rate)
            .num_field("achieved_rate_per_s", arm.achieved)
            .num_field("requests", budget as f64)
            .num_field("served", arm.served as f64)
            .num_field("shed", arm.shed as f64)
            .num_field("shed_frac", arm.shed as f64 / budget as f64)
            .num_field("admitted_p50_ms", arm.p50_ms)
            .num_field("admitted_p99_ms", arm.p99_ms)
            .num_field("adm_admitted", arm.snap.admitted as f64)
            .num_field("adm_shed_inflight", arm.snap.shed_inflight as f64)
            .num_field("adm_shed_slo", arm.snap.shed_slo as f64)
            .num_field("adm_shed_deadline", arm.snap.shed_deadline as f64)
            .num_field("max_inflight", max_inflight as f64)
            .num_field("slo_ms", slo_ms as f64)
            .num_field("conns", conns as f64);
        println!("{}", jw.finish());
    };

    let mut fracs: Vec<f64> = Vec::new();
    for &m in multiples {
        let rate = capacity * m;
        let arm = run_arm(reactor.addr(), rate, budget);
        fracs.push(arm.shed as f64 / budget as f64);
        emit(&mut tw, "reactor", m, rate, &arm);
    }
    // One blocking-front arm at the bottom rate: the legacy front shares
    // the same admission state and must behave, not just the reactor.
    let arm = run_arm(blocking.addr(), capacity * multiples[0], budget);
    emit(&mut tw, "blocking", multiples[0], capacity * multiples[0], &arm);

    let (first, last) = (fracs[0], *fracs.last().expect("at least one reactor arm"));
    assert!(
        last > 0.0,
        "top arm ({}x capacity) must shed — overload has to hit the admission valves",
        multiples.last().expect("multiples")
    );
    assert!(
        last > first,
        "shed fraction must rise past the knee (bottom {first:.3} vs top {last:.3})"
    );
    println!("\nSaturation — open-loop arrival curve, admission armed:\n{}", tw.render());
    println!(
        "Degradation check: past the knee the shed fraction rises while the p99 of \
         admitted requests stays bounded (both asserted) — excess load is refused at \
         the door with `shed: ` error frames, not absorbed into an unbounded queue."
    );
}

/// Kernel-backend mode: the flat SLS kernels per row format, scalar
/// oracle vs. the best backend this CPU detects, on one fixed pooled
/// workload. Outputs are proven bit-identical before anything is
/// timed; per-pass latencies feed a histogram so the JSON carries
/// honest p50/p99 per arm, not just a mean.
fn run_simd(tiny: bool, quick: bool) {
    let (rows, segments, passes) = if tiny {
        (20_000usize, 100usize, 30usize)
    } else if quick {
        (100_000, 400, 60)
    } else {
        (200_000, 1_000, 120)
    };
    let lookups = segments * POOL;
    let simd = backend::detected();
    if simd == KernelBackend::Scalar {
        eprintln!("note: no SIMD backend on this CPU — both arms run the scalar kernels");
    }

    let fp32 = EmbeddingTable::randn_sigma(rows, DIM, 0.1, 0x51F0);
    let fused4 = fp32.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16);
    let fused8 = fp32.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F16);
    // TwoTier keeps quantization setup cheap at bench row counts; the
    // kernel being timed is the same codebook gather either way.
    let cb = fp32.quantize_codebook(CodebookKind::TwoTier { k: 16 }, ScaleBiasDtype::F16);
    let mut rng = Rng::new(0x51F1);
    let indices: Vec<u32> = (0..lookups).map(|_| rng.below(rows) as u32).collect();
    let lengths = vec![POOL as u32; segments];
    let args = SlsArgs::new(&indices, &lengths, rows).unwrap();

    println!(
        "kernel backends: scalar vs {simd} — {rows} rows, d={DIM}, \
         {lookups} pooled rows / {segments} segments, {passes} passes per arm"
    );
    let mut tw = TableWriter::new(vec![
        "format",
        "scalar p50/p99 (ms)",
        "detected p50/p99 (ms)",
        "speedup (p50)",
    ]);
    let views = [
        ("f32", SlsTable::F32(&fp32)),
        ("int4", SlsTable::Fused(&fused4)),
        ("int8", SlsTable::Fused(&fused8)),
        ("codebook", SlsTable::Codebook(&cb)),
    ];
    for (fmt, view) in &views {
        let mut want = vec![0.0f32; segments * DIM];
        let mut out = want.clone();
        // Bit-equality gate: a wrong fast kernel must fail here, not
        // produce an impressive-but-meaningless number below.
        view.sls_with(KernelBackend::Scalar, &args, &mut want);
        view.sls_with(simd, &args, &mut out);
        for (i, (w, g)) in want.iter().zip(&out).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "{fmt}: backends diverged at element {i}");
        }

        let mut time_arm = |kb: KernelBackend| {
            let mut hist = LatencyHistogram::new();
            for _ in 0..passes {
                let t0 = std::time::Instant::now();
                view.sls_with(kb, &args, &mut out);
                hist.record(t0.elapsed());
            }
            let p50 = hist.quantile(0.50).as_nanos() as f64 / 1e6;
            let p99 = hist.quantile(0.99).as_nanos() as f64 / 1e6;
            (p50, p99)
        };
        let (s50, s99) = time_arm(KernelBackend::Scalar);
        let (v50, v99) = time_arm(simd);
        let speedup = s50 / v50;
        tw.row(vec![
            fmt.to_string(),
            format!("{s50:.3}/{s99:.3}"),
            format!("{v50:.3}/{v99:.3}"),
            format!("{speedup:.2}x"),
        ]);
        eprintln!(
            "{fmt}: scalar p50={s50:.3} ms p99={s99:.3} ms, {simd} p50={v50:.3} ms \
             p99={v99:.3} ms ({speedup:.2}x)"
        );
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling_simd")
            .str_field("format", fmt)
            .str_field("backend", &simd.to_string())
            .num_field("rows", rows as f64)
            .num_field("segments", segments as f64)
            .num_field("pooled_rows", lookups as f64)
            .num_field("dim", DIM as f64)
            .num_field("passes", passes as f64)
            .num_field("scalar_p50_ms", s50)
            .num_field("scalar_p99_ms", s99)
            .num_field("simd_p50_ms", v50)
            .num_field("simd_p99_ms", v99)
            .num_field("speedup_p50", speedup);
        println!("{}", jw.finish());
    }
    println!("\nKernel backends — scalar oracle vs {simd}, bit-identical outputs:\n{}", tw.render());
    println!("Dispatch check: the SIMD arm must match the scalar arm bit-for-bit (asserted).");
}

/// Mixed-precision mode: the paper's uniform int4 (FP16) vs the
/// heat-adaptive budget solver at the *same* total byte budget, over
/// Zipf whole-table traffic (alpha 1.5 — the skew the solver trades
/// on).
///
/// The adaptive arm starts from the FP32 masters, warms the heat window
/// with the full request stream, then commits one [`requantize_once`]
/// pass at exactly the uniform-int4 byte budget: hot tables upgrade to
/// int8, the cold tail drops to shared codebooks, total bytes stay at
/// or under the budget. Both arms' accuracy is reported under the same
/// observed heats (the pass's `RequantOutcome` carries both sides), so
/// the heat-weighted L2 delta is apples to apples; the synthetic
/// ranking AUC uses FP32-teacher labels (`sign(row · probe)`) on a
/// shared Zipf event set. The adaptive arm must land strictly below
/// uniform int4 on heat-weighted error at equal bytes — asserted, per
/// the paper-extension acceptance criterion.
///
/// [`requantize_once`]: emberq::shard::ShardedEngine::requantize_once
fn run_mixed(tiny: bool, quick: bool) {
    let (num_tables, rows, dim, requests, reps) = if tiny {
        (8usize, 1_024usize, 16usize, 400usize, 2usize)
    } else if quick {
        (8, 4_096, 32, 1_500, 3)
    } else {
        (12, 16_384, 32, 6_000, 5)
    };
    let max_batch = 16usize;
    let shards = 4usize;
    let q = GreedyQuantizer::default();
    let fp32: Vec<EmbeddingTable> = (0..num_tables)
        .map(|t| EmbeddingTable::randn_sigma(rows, dim, 0.1, 0x6C00 + t as u64))
        .collect();
    let zipf = Zipf::new(num_tables, 1.5);
    let mut rng = Rng::new(0x6C6C);
    let reqs: Vec<Request> = (0..requests)
        .map(|_| {
            let mut pools = vec![0usize; num_tables];
            for _ in 0..24 {
                pools[zipf.sample(&mut rng)] += 3;
            }
            Request {
                ids: pools
                    .iter()
                    .map(|&pool| (0..pool).map(|_| rng.below(rows) as u32).collect())
                    .collect(),
            }
        })
        .collect();
    // The shared budget: exactly the bytes of uniform int4 (FP16).
    let budget = num_tables * rows * (dim.div_ceil(2) + 4);

    // Ranking-eval events shared by both arms: Zipf-weighted (table,
    // row, probe) triples with an FP32-teacher label — does the
    // quantized engine still rank what the masters rank?
    let events = if tiny { 1_000usize } else { 4_000 };
    let mut erng = Rng::new(0x6C6D);
    let evs: Vec<(usize, u32)> =
        (0..events).map(|_| (zipf.sample(&mut erng), erng.below(rows) as u32)).collect();
    let probes: Vec<Vec<f32>> = (0..events).map(|_| erng.normal_vec(dim, 1.0)).collect();
    let labels: Vec<f32> = evs
        .iter()
        .zip(&probes)
        .map(|(&(t, r), u)| {
            let dot: f32 = fp32[t].row(r as usize).iter().zip(u).map(|(a, b)| a * b).sum();
            if dot > 0.0 {
                1.0
            } else {
                0.0
            }
        })
        .collect();
    let score_events = |engine: &ShardedEngine| -> Vec<f32> {
        evs.iter()
            .zip(&probes)
            .map(|(&(t, r), u)| {
                let ids: Vec<Vec<u32>> = (0..num_tables)
                    .map(|tt| if tt == t { vec![r] } else { Vec::new() })
                    .collect();
                let out = engine.lookup(&Request { ids });
                out[t * dim..(t + 1) * dim].iter().zip(u).map(|(a, b)| a * b).sum()
            })
            .collect()
    };

    println!(
        "mixed-precision workload: {num_tables} whole tables × {rows} rows × d={dim}, \
         {requests} requests (Zipf table popularity, alpha 1.5), equal byte budget \
         {budget} B (= uniform int4/FP16)"
    );

    // Adaptive arm setup: FP32 masters in, heat warmed by the same
    // traffic the timed passes use, one budgeted pass committed online.
    let adaptive = ShardedEngine::start(
        TableSet::new(fp32.iter().map(|t| AnyTable::F32(t.clone())).collect()),
        &ShardConfig {
            num_shards: shards,
            small_table_rows: usize::MAX, // whole tables: per-table heat
            ..Default::default()
        },
    );
    let fw = adaptive.feature_width();
    let mut out = vec![0.0f32; max_batch * fw];
    for batch in reqs.chunks(max_batch) {
        adaptive.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
    }
    let outcome = adaptive.requantize_once(budget, &q).expect("budgeted requantization");
    assert!(outcome.changed > 0, "FP32 masters cannot fit the int4 budget unchanged");
    assert_eq!(outcome.uniform_int4_bytes, budget);
    assert!(outcome.total_bytes <= budget, "{} B > {budget} B", outcome.total_bytes);
    // The acceptance criterion: at equal bytes, heat-adaptive formats
    // buy back accuracy where the traffic actually reads.
    assert!(
        outcome.weighted_err < outcome.uniform_int4_err,
        "heat-adaptive must be strictly below uniform int4 at equal bytes: \
         {} vs {}",
        outcome.weighted_err,
        outcome.uniform_int4_err
    );

    // Uniform arm: the paper baseline, quantized offline at the same
    // bytes.
    let uniform = ShardedEngine::start(
        TableSet::new(
            fp32.iter()
                .map(|t| AnyTable::Fused(t.quantize_fused(&q, 4, ScaleBiasDtype::F16)))
                .collect(),
        ),
        &ShardConfig {
            num_shards: shards,
            small_table_rows: usize::MAX,
            ..Default::default()
        },
    );

    let mut tw = TableWriter::new(vec![
        "arm",
        "payload bytes",
        "batch p50/p99 (ms)",
        "heat-weighted L2",
        "ranking AUC",
    ]);
    let arms: [(&str, &ShardedEngine, f64, usize); 2] = [
        ("uniform-int4", &uniform, outcome.uniform_int4_l2(), budget),
        ("adaptive", &adaptive, outcome.weighted_l2(), outcome.total_bytes),
    ];
    let mut aucs = [0.0f64; 2];
    for (i, &(label, engine, l2, bytes)) in arms.iter().enumerate() {
        for batch in reqs.chunks(max_batch) {
            engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
        }
        let mut hist = LatencyHistogram::new();
        for _ in 0..reps {
            for batch in reqs.chunks(max_batch) {
                let t0 = std::time::Instant::now();
                engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
                hist.record(t0.elapsed());
            }
        }
        let scores = score_events(engine);
        let auc = roc_auc(&scores, &labels);
        aucs[i] = auc;
        assert!(auc > 0.8, "{label}: quantization must preserve the FP32 ranking (auc {auc:.3})");
        let p50 = hist.quantile(0.50).as_nanos() as f64 / 1e6;
        let p99 = hist.quantile(0.99).as_nanos() as f64 / 1e6;
        tw.row(vec![
            label.to_string(),
            bytes.to_string(),
            format!("{p50:.3}/{p99:.3}"),
            format!("{l2:.5}"),
            format!("{auc:.4}"),
        ]);
        eprintln!(
            "{label}: batch p50={p50:.3} ms p99={p99:.3} ms, {bytes} B, \
             heat-weighted L2 {l2:.5}, auc {auc:.4}"
        );
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling_mixed")
            .str_field("arm", label)
            .num_field("shards", shards as f64)
            .num_field("tables", num_tables as f64)
            .num_field("rows", rows as f64)
            .num_field("dim", dim as f64)
            .num_field("requests", requests as f64)
            .num_field("budget_bytes", budget as f64)
            .num_field("payload_bytes", bytes as f64)
            .num_field("requantized_groups", (if i == 1 { outcome.changed } else { 0 }) as f64)
            .num_field("batch_p50_ms", p50)
            .num_field("batch_p99_ms", p99)
            .num_field("heat_weighted_l2", l2)
            .num_field("ranking_auc", auc)
            .num_field("eval_events", events as f64);
        println!("{}", jw.finish());
    }
    println!("\nMixed precision — equal bytes, heat-adaptive vs uniform int4:\n{}", tw.render());
    println!(
        "Budget check: at {budget} B the adaptive assignment ({} groups rebuilt) cut \
         heat-weighted L2 from {:.5} to {:.5} ({:+.1}% err) with AUC {:.4} -> {:.4} — \
         strictly-lower heat-weighted error is asserted.",
        outcome.changed,
        outcome.uniform_int4_l2(),
        outcome.weighted_l2(),
        (outcome.weighted_err / outcome.uniform_int4_err - 1.0) * 100.0,
        aucs[0],
        aucs[1],
    );
}

/// Skewed-workload mode: Zipf table popularity over whole fused tables,
/// static placement vs. stealing + runtime re-replication.
fn run_skewed(tiny: bool, quick: bool) {
    let (num_tables, rows, dim, requests, reps) = if tiny {
        (12usize, 1_500usize, 32usize, 600usize, 2usize)
    } else if quick {
        (12, 8_000, 64, 2_000, 3)
    } else {
        (16, 40_000, 64, 8_000, 5)
    };
    let max_batch = 16usize;
    let fp32: Vec<EmbeddingTable> = (0..num_tables)
        .map(|t| EmbeddingTable::randn_sigma(rows, dim, 0.1, 0x5E00 + t as u64))
        .collect();
    let mk_set = || {
        TableSet::new(
            fp32.iter()
                .map(|t| AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)))
                .collect(),
        )
    };
    // Zipf-popular tables: each request draws table picks from a Zipf
    // over table ids, pooling a few rows per pick — hot tables get big
    // segments, cold ones small or empty.
    let zipf = Zipf::new(num_tables, 1.1);
    let mut rng = Rng::new(0x5E5E);
    let reqs: Vec<Request> = (0..requests)
        .map(|_| {
            let mut pools = vec![0usize; num_tables];
            for _ in 0..24 {
                pools[zipf.sample(&mut rng)] += 3;
            }
            Request {
                ids: pools
                    .iter()
                    .map(|&pool| (0..pool).map(|_| rng.below(rows) as u32).collect())
                    .collect(),
            }
        })
        .collect();
    println!(
        "skewed workload: {num_tables} whole INT4 tables × {rows} rows × d={dim}, \
         {requests} requests (Zipf table popularity, alpha 1.1), batches of {max_batch}"
    );
    for shards in [4usize, 8] {
        let mut baseline: Option<Vec<f32>> = None;
        for (label, steal, adapt) in [("static", false, false), ("adaptive", true, true)] {
            let engine = ShardedEngine::start(
                mk_set(),
                &ShardConfig {
                    num_shards: shards,
                    small_table_rows: usize::MAX, // whole tables: the skew hazard
                    steal,
                    ..Default::default()
                },
            );
            let fw = engine.feature_width();
            let mut out = vec![0.0f32; max_batch * fw];
            // Warm pass (drives observed_loads); the adaptive arm then
            // runs one runtime re-replication pass off those loads —
            // the same pass `--rebalance-interval` runs on a timer.
            for batch in reqs.chunks(max_batch) {
                engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
            }
            if adapt {
                engine.rebalance_once();
            }
            let mut hist = LatencyHistogram::new();
            for _ in 0..reps {
                for batch in reqs.chunks(max_batch) {
                    let t0 = std::time::Instant::now();
                    engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
                    hist.record(t0.elapsed());
                }
            }
            // Bit-exactness across arms: adaptive must not move a bit.
            let first = &reqs[..max_batch];
            let mut check = vec![0.0f32; max_batch * fw];
            engine.lookup_batch_into(first, &mut check);
            match &baseline {
                None => baseline = Some(check),
                Some(b) => assert_eq!(b, &check, "arms diverged at {shards} shards"),
            }
            let p50 = hist.quantile(0.50).as_nanos() as f64 / 1e6;
            let p99 = hist.quantile(0.99).as_nanos() as f64 / 1e6;
            let steals = engine.steal_count();
            let rb = engine.rebalance_stats();
            eprintln!(
                "shards={shards} {label}: batch p50={p50:.3} ms p99={p99:.3} ms, \
                 {steals} steals, {} rebalances (+{} replicas)",
                rb.rebalances, rb.replicas_added
            );
            let mut jw = JsonWriter::new();
            jw.str_field("bench", "shard_scaling_skewed")
                .str_field("arm", label)
                .num_field("shards", shards as f64)
                .num_field("tables", num_tables as f64)
                .num_field("rows", rows as f64)
                .num_field("requests", requests as f64)
                .num_field("steal", u64::from(steal) as f64)
                .num_field("batch_p50_ms", p50)
                .num_field("batch_p99_ms", p99)
                .num_field("steals", steals as f64)
                .num_field("rebalances", rb.rebalances as f64)
                .num_field("replicas_added", rb.replicas_added as f64)
                .num_field("replicas_retired", rb.replicas_retired as f64);
            println!("{}", jw.finish());
        }
    }
    println!(
        "\nAdaptive check: with Zipf table skew, stealing + runtime re-replication \
         should show lower batch p99 than static placement, bit-exactly."
    );
}

/// Tiered-storage mode: the same Zipf whole-table workload served with a
/// resident-bytes budget at ~45% of the table bytes (hot tables stay in
/// RAM, the cold tail spills and promotes on touch) vs. an unlimited
/// engine — the cost of exceeding RAM, quantified, with bit-exactness
/// asserted across the arms.
fn run_spill(tiny: bool, quick: bool) {
    let (num_tables, rows, dim, requests, reps) = if tiny {
        (12usize, 1_500usize, 32usize, 400usize, 2usize)
    } else if quick {
        (12, 8_000, 64, 1_500, 3)
    } else {
        (16, 40_000, 64, 6_000, 5)
    };
    let max_batch = 16usize;
    let shards = 4usize;
    let fp32: Vec<EmbeddingTable> = (0..num_tables)
        .map(|t| EmbeddingTable::randn_sigma(rows, dim, 0.1, 0x5F00 + t as u64))
        .collect();
    let mk_set = || {
        TableSet::new(
            fp32.iter()
                .map(|t| AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)))
                .collect(),
        )
    };
    // Quantize once for the first arm and read the size off that set;
    // the second arm re-quantizes (engines consume their sets).
    let mut prebuilt = Some(mk_set());
    let logical = prebuilt.as_ref().expect("prebuilt set").size_bytes();
    let budget = logical * 45 / 100;
    let zipf = Zipf::new(num_tables, 1.1);
    let mut rng = Rng::new(0x5F5F);
    let reqs: Vec<Request> = (0..requests)
        .map(|_| {
            let mut pools = vec![0usize; num_tables];
            for _ in 0..24 {
                pools[zipf.sample(&mut rng)] += 3;
            }
            Request {
                ids: pools
                    .iter()
                    .map(|&pool| (0..pool).map(|_| rng.below(rows) as u32).collect())
                    .collect(),
            }
        })
        .collect();
    println!(
        "tiered workload: {num_tables} whole INT4 tables × {rows} rows × d={dim} \
         ({logical} B), Zipf traffic, resident budget {budget} B (~45%)"
    );
    let mut baseline: Option<Vec<f32>> = None;
    for (label, resident_budget) in [("resident", None), ("tiered", Some(budget))] {
        let engine = ShardedEngine::start(
            prebuilt.take().unwrap_or_else(mk_set),
            &ShardConfig {
                num_shards: shards,
                small_table_rows: usize::MAX, // whole tables: per-table tiering
                resident_budget,
                ..Default::default()
            },
        );
        let fw = engine.feature_width();
        let mut out = vec![0.0f32; max_batch * fw];
        // Warm pass: loads the Zipf-hot working set into the RAM tier.
        for batch in reqs.chunks(max_batch) {
            engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
        }
        let mut hist = LatencyHistogram::new();
        for _ in 0..reps {
            for batch in reqs.chunks(max_batch) {
                let t0 = std::time::Instant::now();
                engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
                hist.record(t0.elapsed());
            }
        }
        // Bit-exactness across tiers: spilling must not move a bit.
        let first = &reqs[..max_batch];
        let mut check = vec![0.0f32; max_batch * fw];
        engine.lookup_batch_into(first, &mut check);
        match &baseline {
            None => baseline = Some(check),
            Some(b) => assert_eq!(b, &check, "tiered arm diverged from resident arm"),
        }
        let resident: usize = engine.shard_bytes().iter().sum();
        if let Some(b) = resident_budget {
            assert!(resident <= b, "budget violated: {resident} > {b}");
        }
        let p50 = hist.quantile(0.50).as_nanos() as f64 / 1e6;
        let p99 = hist.quantile(0.99).as_nanos() as f64 / 1e6;
        let st = engine.store_stats().unwrap_or_default();
        eprintln!(
            "{label}: batch p50={p50:.3} ms p99={p99:.3} ms, resident {resident} B, \
             {} promotions / {} demotions, {} B spill reads, {} spill errors",
            st.promotions, st.demotions, st.spill_read_bytes, st.spill_errors
        );
        assert_eq!(st.spill_errors, 0);
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling_spill")
            .str_field("arm", label)
            .num_field("shards", shards as f64)
            .num_field("tables", num_tables as f64)
            .num_field("rows", rows as f64)
            .num_field("requests", requests as f64)
            .num_field("table_bytes", logical as f64)
            .num_field("resident_budget", resident_budget.unwrap_or(0) as f64)
            .num_field("resident_bytes", resident as f64)
            .num_field("spilled_bytes", engine.spilled_bytes() as f64)
            .num_field("batch_p50_ms", p50)
            .num_field("batch_p99_ms", p99)
            .num_field("promotions", st.promotions as f64)
            .num_field("demotions", st.demotions as f64)
            .num_field("spill_read_bytes", st.spill_read_bytes as f64);
        println!("{}", jw.finish());
    }
    println!(
        "\nTiered check: the spill arm serves the same bits as the resident arm \
         while holding only the budget's bytes in RAM (Zipf-hot tables resident, \
         cold tail on disk)."
    );
}

/// Sync-vs-async spill I/O: identical budgeted workload, promote stalls
/// forced by a `spill_all` storm before every measured pass. The sync
/// arm demotes inline (no pool, no prefetch); the async arm overlaps
/// demote writes and promote reads on the background pool.
fn run_spill_async(tiny: bool, quick: bool) {
    let (num_tables, rows, dim, requests, reps) = if tiny {
        (4usize, 4_000usize, 32usize, 200usize, 2usize)
    } else if quick {
        (4, 16_000, 64, 800, 3)
    } else {
        (6, 80_000, 64, 3_000, 5)
    };
    let max_batch = 16usize;
    let shards = 4usize;
    let fp32: Vec<EmbeddingTable> = (0..num_tables)
        .map(|t| EmbeddingTable::randn_sigma(rows, dim, 0.1, 0x6A00 + t as u64))
        .collect();
    let mk_set = || {
        TableSet::new(
            fp32.iter()
                .map(|t| AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)))
                .collect(),
        )
    };
    let mut prebuilt = Some(mk_set());
    let logical = prebuilt.as_ref().expect("prebuilt set").size_bytes();
    let budget = logical * 45 / 100;
    // Spanning pooled lookups over row-wise chunks: after a spill_all,
    // each segment touches several spilled chunks — exactly the shape
    // the overlapping prefetch reads exist for.
    let mut rng = Rng::new(0x6A6A);
    let reqs: Vec<Request> = (0..requests)
        .map(|_| Request {
            ids: (0..num_tables)
                .map(|_| (0..POOL / 2).map(|_| rng.below(rows) as u32).collect())
                .collect(),
        })
        .collect();
    println!(
        "async-spill workload: {num_tables} row-wise INT4 tables × {rows} rows × d={dim} \
         ({logical} B), budget {budget} B (~45%), spill_all storm before every pass"
    );
    let mut baseline: Option<Vec<f32>> = None;
    for (label, io_threads, prefetch_window) in [("sync", 0usize, 0usize), ("async", 2, 2)] {
        let engine = ShardedEngine::start(
            prebuilt.take().unwrap_or_else(mk_set),
            &ShardConfig {
                num_shards: shards,
                small_table_rows: 0, // row-wise chunks everywhere
                resident_budget: Some(budget),
                spill_io_threads: io_threads,
                prefetch_window,
                ..Default::default()
            },
        );
        let fw = engine.feature_width();
        let mut out = vec![0.0f32; max_batch * fw];
        // Warm once so the write-once spill files exist before timing:
        // the measured passes then isolate promote stalls + tier flips,
        // not first-time serialization cost.
        for batch in reqs.chunks(max_batch) {
            engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
        }
        engine.spill_all().expect("pre-bench demote-all");
        let mut hist = LatencyHistogram::new();
        for _ in 0..reps {
            engine.spill_all().expect("storm demote-all");
            for batch in reqs.chunks(max_batch) {
                let t0 = std::time::Instant::now();
                engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
                hist.record(t0.elapsed());
            }
        }
        // Bit-exactness across arms: async I/O must not move a bit.
        let first = &reqs[..max_batch];
        let mut check = vec![0.0f32; max_batch * fw];
        engine.lookup_batch_into(first, &mut check);
        match &baseline {
            None => baseline = Some(check),
            Some(b) => assert_eq!(b, &check, "async arm diverged from sync arm"),
        }
        let resident: usize = engine.shard_bytes().iter().sum();
        assert!(resident <= budget, "budget violated: {resident} > {budget}");
        let p50 = hist.quantile(0.50).as_nanos() as f64 / 1e6;
        let p99 = hist.quantile(0.99).as_nanos() as f64 / 1e6;
        let st = engine.store_stats().unwrap_or_default();
        assert_eq!(st.spill_errors, 0);
        eprintln!(
            "{label} (io_threads={io_threads}): batch p50={p50:.3} ms p99={p99:.3} ms, \
             {} promotions / {} demotions, {} prefetches, {} B streamed",
            st.promotions, st.demotions, st.prefetches, st.demote_stream_bytes
        );
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling_spill_async")
            .str_field("arm", label)
            .num_field("shards", shards as f64)
            .num_field("io_threads", io_threads as f64)
            .num_field("prefetch_window", prefetch_window as f64)
            .num_field("tables", num_tables as f64)
            .num_field("rows", rows as f64)
            .num_field("requests", requests as f64)
            .num_field("table_bytes", logical as f64)
            .num_field("resident_budget", budget as f64)
            .num_field("batch_p50_ms", p50)
            .num_field("batch_p99_ms", p99)
            .num_field("promotions", st.promotions as f64)
            .num_field("demotions", st.demotions as f64)
            .num_field("prefetches", st.prefetches as f64)
            .num_field("spill_read_bytes", st.spill_read_bytes as f64)
            .num_field("demote_stream_bytes", st.demote_stream_bytes as f64);
        println!("{}", jw.finish());
    }
    println!(
        "\nAsync-spill check: the async arm should show lower promote-stall p50/p99 \
         than the sync arm on the same budgeted workload, bit-exactly (overlapping \
         prefetch reads + off-request demote writes)."
    );
}

/// Live-update churn: batched reads with and without a background
/// updater swapping table versions underneath them. The update program
/// is deterministic, so the churned engine's at-rest state has exactly
/// one correct answer: the masters with every batch applied,
/// requantized — asserted per sampled row after the updater joins.
fn run_update_churn(tiny: bool, quick: bool) {
    let (num_tables, rows, dim, requests, reps, update_batches, update_rows) = if tiny {
        (4usize, 2_000usize, 32usize, 300usize, 2usize, 24usize, 8usize)
    } else if quick {
        (6, 8_000, 64, 1_000, 3, 64, 16)
    } else {
        (8, 40_000, 64, 4_000, 5, 200, 32)
    };
    let max_batch = 16usize;
    let shards = 4usize;
    let fp32: Vec<EmbeddingTable> = (0..num_tables)
        .map(|t| EmbeddingTable::randn_sigma(rows, dim, 0.1, 0x6B00 + t as u64))
        .collect();
    let mk_set = || {
        TableSet::new(
            fp32.iter()
                .map(|t| AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)))
                .collect(),
        )
    };
    let mut rng = Rng::new(0x6B6B);
    let reqs: Vec<Request> = (0..requests)
        .map(|_| Request {
            ids: (0..num_tables)
                .map(|_| (0..POOL / 4).map(|_| rng.below(rows) as u32).collect())
                .collect(),
        })
        .collect();
    // The deterministic update program both arms' final checks derive
    // from (the read-only arm simply never runs it).
    let mut urng = Rng::new(0x6B6C);
    let program: Vec<(usize, Vec<(u32, Vec<f32>)>)> = (0..update_batches)
        .map(|_| {
            let t = urng.below(num_tables);
            let batch = (0..update_rows)
                .map(|_| (urng.below(rows) as u32, urng.normal_vec(dim, 0.1)))
                .collect();
            (t, batch)
        })
        .collect();
    println!(
        "update-churn workload: {num_tables} row-wise INT4 tables × {rows} rows × d={dim}, \
         {requests} requests/pass × {reps} passes; churn arm commits {update_batches} \
         update batches × {update_rows} rows concurrently"
    );
    for (label, churn) in [("read-only", false), ("churn", true)] {
        let engine = ShardedEngine::start(
            mk_set(),
            &ShardConfig { num_shards: shards, small_table_rows: 0, ..Default::default() },
        );
        let fw = engine.feature_width();
        let mut out = vec![0.0f32; max_batch * fw];
        for batch in reqs.chunks(max_batch) {
            engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
        }
        let mut hist = LatencyHistogram::new();
        std::thread::scope(|s| {
            let updater = churn.then(|| {
                let (engine, program) = (&engine, &program);
                s.spawn(move || {
                    for (t, batch) in program {
                        engine
                            .update_table(*t, batch, &AsymQuantizer)
                            .expect("churn commit");
                        // Spread commits across the measured passes.
                        std::thread::sleep(std::time::Duration::from_micros(200));
                    }
                })
            });
            for _ in 0..reps {
                for batch in reqs.chunks(max_batch) {
                    let t0 = std::time::Instant::now();
                    engine.lookup_batch_into(batch, &mut out[..batch.len() * fw]);
                    hist.record(t0.elapsed());
                }
            }
            if let Some(h) = updater {
                h.join().expect("updater thread");
            }
        });
        let expected_version = if churn { 1 + update_batches as u64 } else { 1 };
        assert_eq!(engine.version(), expected_version, "every commit bumps once");
        // At-rest bit-exactness: the reference is the masters with the
        // program applied, requantized whole — the single-row patch
        // path must land on identical bytes.
        let reference = {
            let mut masters = fp32.clone();
            if churn {
                for (t, batch) in &program {
                    for (id, vals) in batch {
                        masters[*t].row_mut(*id as usize).copy_from_slice(vals);
                    }
                }
            }
            TableSet::new(
                masters
                    .iter()
                    .map(|t| {
                        AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16))
                    })
                    .collect(),
            )
        };
        let stride = (rows / 1024).max(1);
        for id in (0..rows).step_by(stride) {
            let req = Request { ids: vec![vec![id as u32]; num_tables] };
            let got = engine.lookup(&req);
            let mut want = vec![0.0f32; fw];
            for t in 0..num_tables {
                let lo = reference.offset_of(t);
                reference.pool(t, &req.ids[t], &mut want[lo..lo + dim]);
            }
            assert_eq!(got, want, "{label}: row {id} diverged from the requantized masters");
        }
        let p50 = hist.quantile(0.50).as_nanos() as f64 / 1e6;
        let p99 = hist.quantile(0.99).as_nanos() as f64 / 1e6;
        eprintln!(
            "{label}: batch p50={p50:.3} ms p99={p99:.3} ms, final version {}",
            engine.version()
        );
        let mut jw = JsonWriter::new();
        jw.str_field("bench", "shard_scaling_update_churn")
            .str_field("arm", label)
            .num_field("shards", shards as f64)
            .num_field("tables", num_tables as f64)
            .num_field("rows", rows as f64)
            .num_field("requests", requests as f64)
            .num_field("update_batches", (if churn { update_batches } else { 0 }) as f64)
            .num_field("update_rows", update_rows as f64)
            .num_field("final_version", expected_version as f64)
            .num_field("batch_p50_ms", p50)
            .num_field("batch_p99_ms", p99);
        println!("{}", jw.finish());
    }
    println!(
        "\nUpdate-churn check: concurrent snapshot swaps should cost little read p50 \
         and bounded p99 (placement swaps are pointer flips; quantization happens \
         off the read path), with the at-rest state bit-identical to a full \
         requantization of the updated masters."
    );
}
