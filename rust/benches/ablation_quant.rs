//! Ablations on the design choices DESIGN.md calls out:
//!
//! 1. **Eq. 1 vs zero-point mapping** (paper footnote 2): bias-anchored
//!    vs zero-anchored uniform grids on embedding rows vs ReLU-like data.
//! 2. **GREEDY hyperparameters**: the b/r trade-off (quality vs time).
//! 3. **2-D GSS** (paper: "too consuming"): cost and quality vs GREEDY.
//! 4. **Incremental refresh**: periodic re-quantization cost, full table
//!    vs dirty-rows-only (the continuous-learning story of §2).
//!
//! ```bash
//! cargo bench --bench ablation_quant
//! ```

use emberq::eval::TableWriter;
use emberq::quant::{
    quant_sq_error, AsymQuantizer, Gss2dQuantizer, GreedyQuantizer, Quantizer,
    ZeroPointQuantizer,
};
use emberq::table::{EmbeddingTable, ScaleBiasDtype, TableRefresher};
use emberq::util::bench::measure;
use emberq::util::Rng;

fn mean_rel_l2(q: &dyn Quantizer, rows: &[Vec<f32>]) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for r in rows {
        num += quant_sq_error(r, q.clip(r, 4), 4);
        den += emberq::util::stats::l2_sq(r);
    }
    (num / den).sqrt()
}

fn main() {
    let mut rng = Rng::new(0xAB1A7E);

    // ---- 1: Eq.1 vs zero-point ------------------------------------
    println!("== ablation 1: Eq.1 (ASYM) vs zero-point mapping ==");
    let emb_rows: Vec<Vec<f32>> = (0..200)
        .map(|_| {
            let mu = rng.uniform_in(-0.5, 0.5) as f32;
            (0..64).map(|_| mu + (rng.normal() as f32) * 0.2).collect()
        })
        .collect();
    let relu_rows: Vec<Vec<f32>> = (0..200)
        .map(|_| {
            (0..64)
                .map(|_| (rng.normal() as f32).max(0.0)) // ~50% exact zeros
                .collect()
        })
        .collect();
    let mut tw = TableWriter::new(vec!["data", "ASYM (Eq.1)", "ASYM-ZP"]);
    for (name, rows) in [("embedding rows", &emb_rows), ("ReLU activations", &relu_rows)] {
        tw.row(vec![
            name.to_string(),
            format!("{:.5}", mean_rel_l2(&AsymQuantizer, rows)),
            format!("{:.5}", mean_rel_l2(&ZeroPointQuantizer, rows)),
        ]);
    }
    println!("{}", tw.render());
    println!("(footnote 2: Eq.1 wins on embedding rows; ZP exists for zero-heavy data)\n");

    // ---- 2: GREEDY b/r sweep ---------------------------------------
    println!("== ablation 2: GREEDY hyperparameters ==");
    let mut tw = TableWriter::new(vec!["b", "r", "norm. l2 (d=64)", "time/row"]);
    let rows: Vec<Vec<f32>> = (0..100).map(|_| rng.normal_vec(64, 1.0)).collect();
    for (b, r) in [(50u32, 0.16), (200, 0.16), (200, 0.5), (1000, 0.5), (2000, 0.8)] {
        let q = GreedyQuantizer { b, r };
        let l2 = mean_rel_l2(&q, &rows);
        let m = measure(1, 5, || {
            for row in rows.iter().take(20) {
                std::hint::black_box(q.clip(row, 4));
            }
        });
        tw.row(vec![
            b.to_string(),
            format!("{r}"),
            format!("{l2:.5}"),
            format!("{:.1?}", m.median / 20),
        ]);
    }
    println!("{}", tw.render());
    println!("(paper default b=200/r=0.16 sits at the knee; opt b=1000/r=0.5 buys ~2%)\n");

    // ---- 3: 2-D GSS vs GREEDY --------------------------------------
    println!("== ablation 3: 2-D golden section search (the road not taken) ==");
    let mut tw = TableWriter::new(vec!["method", "norm. l2 (d=64)", "time/row"]);
    let greedy = GreedyQuantizer::default();
    let gss2d = Gss2dQuantizer::default();
    for (name, q) in [("GREEDY", &greedy as &dyn Quantizer), ("GSS-2D", &gss2d)] {
        let l2 = mean_rel_l2(q, &rows);
        let m = measure(1, 5, || {
            for row in rows.iter().take(20) {
                std::hint::black_box(q.clip(row, 4));
            }
        });
        tw.row(vec![
            name.to_string(),
            format!("{l2:.5}"),
            format!("{:.1?}", m.median / 20),
        ]);
    }
    println!("{}", tw.render());
    println!("(paper §3: nested GSS costs more for no quality gain on short rows)\n");

    // ---- 4: incremental refresh ------------------------------------
    println!("== ablation 4: periodic re-quantization, full vs incremental ==");
    let rows_n = 50_000usize;
    let mut table = EmbeddingTable::randn_sigma(rows_n, 64, 0.1, 4242);
    let q = GreedyQuantizer::default();
    let mut refresher = TableRefresher::new(&table, &q, 4, ScaleBiasDtype::F16);
    // A training interval touches the Zipf head: 1% of rows.
    let dirty: Vec<usize> = (0..rows_n / 100).map(|_| rng.below(rows_n / 10)).collect();
    for &r in &dirty {
        for v in table.row_mut(r) {
            *v += (rng.normal() as f32) * 0.01;
        }
        refresher.mark_dirty(r);
    }
    let m_full = measure(0, 3, || {
        std::hint::black_box(table.quantize_fused(&q, 4, ScaleBiasDtype::F16))
    });
    let m_incr = measure(0, 1, || {
        // Measure one realistic refresh (marks are consumed, so re-mark).
        for &r in &dirty {
            refresher.mark_dirty(r);
        }
        refresher.refresh(&table, &q)
    });
    let mut tw = TableWriter::new(vec!["strategy", "rows requantized", "time"]);
    tw.row(vec![
        "full table".to_string(),
        rows_n.to_string(),
        format!("{:.1?}", m_full.median),
    ]);
    tw.row(vec![
        "incremental (1% dirty)".to_string(),
        dirty.len().to_string(),
        format!("{:.1?}", m_incr.median),
    ]);
    println!("{}", tw.render());
    println!(
        "speedup {:.0}× — periodic re-quantization scales with traffic, not table size.",
        m_full.secs() / m_incr.secs().max(1e-9)
    );
}
