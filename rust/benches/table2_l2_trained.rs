//! Table 2 reproduction: normalized ℓ2 loss of every quantization method
//! on *trained* embedding tables, for d ∈ {8, 16, 32, 64, 128}.
//!
//! Each dim trains a scaled-down DLRM on the synthetic Criteo stream
//! (Adagrad, batch 100 — the paper's §5 recipe), then quantizes table 0.
//!
//! ```bash
//! cargo bench --bench table2_l2_trained [-- --quick]
//! ```

use emberq::data::{CriteoConfig, SyntheticCriteo};
use emberq::eval::{normalized_l2_method, TableWriter};
use emberq::model::{Dlrm, DlrmConfig, Trainer, TrainerConfig};
use emberq::quant::method_by_name;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};

fn trained_table(dim: usize, steps: usize) -> EmbeddingTable {
    let dcfg = CriteoConfig { num_sparse: 4, rows_per_table: 2_000, ..Default::default() };
    let mcfg = DlrmConfig {
        num_tables: 4,
        rows_per_table: 2_000,
        dim,
        dense_dim: dcfg.dense_dim,
        hidden: vec![128, 128],
        seed: 0x7AB2 + dim as u64,
    };
    let mut model = Dlrm::new(mcfg);
    let mut data = SyntheticCriteo::train(dcfg);
    Trainer::new(TrainerConfig { batch: 100, steps, log_every: steps, ..Default::default() })
        .train(&mut model, &mut data);
    model.tables.swap_remove(0)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let steps = if quick { 150 } else { 600 };
    let dims = [8usize, 16, 32, 64, 128];
    // (label, method, nbits, sb) in the paper's row order.
    use ScaleBiasDtype::{F16, F32};
    let rows: Vec<(&str, &str, u32, ScaleBiasDtype)> = vec![
        ("ASYM-8BITS", "ASYM", 8, F32),
        ("SYM", "SYM", 4, F32),
        ("GSS", "GSS", 4, F32),
        ("ASYM", "ASYM", 4, F32),
        ("HIST-APPRX", "HIST-APPRX", 4, F32),
        ("HIST-BRUTE", "HIST-BRUTE", 4, F32),
        ("ACIQ", "ACIQ", 4, F32),
        ("GREEDY", "GREEDY", 4, F32),
        ("GREEDY (FP16)", "GREEDY", 4, F16),
        ("KMEANS-CLS (FP16)", "KMEANS-CLS", 4, F16),
        ("KMEANS (FP16)", "KMEANS", 4, F16),
    ];

    let tables: Vec<(usize, EmbeddingTable)> = dims
        .iter()
        .map(|&d| {
            eprintln!("training d={d}...");
            (d, trained_table(d, steps))
        })
        .collect();

    let mut tw = TableWriter::new(
        std::iter::once("method".to_string())
            .chain(dims.iter().map(|d| format!("d={d}")))
            .collect::<Vec<_>>(),
    );
    for (label, name, nbits, sb) in &rows {
        let method = method_by_name(name).unwrap();
        let mut out = vec![label.to_string()];
        for (_, table) in &tables {
            let l2 = normalized_l2_method(table, &method, *nbits, *sb);
            out.push(format!("{l2:.5}"));
        }
        eprintln!("done {label}");
        tw.row(out);
    }
    println!("\nTable 2 — normalized l2 on trained tables:\n{}", tw.render());
    println!(
        "Paper shape: GREEDY smallest among 4-bit uniform; KMEANS(FP16) ~0 at d<=16;\n\
         ASYM-8BITS ~15x below the 4-bit methods; GREEDY==GREEDY(FP16) to 4+ decimals."
    );
}
