//! Table 1 reproduction: SparseLengthsSum computational throughput in
//! billion element sums per second, FP32 / INT8 / INT4, cache
//! non-resident and cache resident.
//!
//! Paper setup: single core, Xeon Gold 6138, LLC flushed between runs for
//! the non-resident case. We reproduce the *shape*: INT4 moves `d/2+4`
//! bytes/row vs `d+8` (INT8) and `4d` (FP32), so its throughput overtakes
//! both as `d` grows and the table leaves cache.
//!
//! ```bash
//! cargo bench --bench table1_sls_throughput
//! ```

use emberq::eval::TableWriter;
use emberq::quant::AsymQuantizer;
use emberq::sls::{sls_f32, sls_fused, CacheFlusher, SlsArgs};
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::bench::{measure, measure_with_setup};
use emberq::util::Rng;

/// Rows pooled per measurement (paper pools large batches).
const LOOKUPS: usize = 200_000;
const SEGMENTS: usize = 2_000;

fn workload(rows: usize, rng: &mut Rng) -> (Vec<u32>, Vec<u32>) {
    let indices: Vec<u32> = (0..LOOKUPS).map(|_| rng.below(rows) as u32).collect();
    let lengths = vec![(LOOKUPS / SEGMENTS) as u32; SEGMENTS];
    (indices, lengths)
}

/// The paper's metric: billion *element* sums per second (each pooled row
/// contributes `d` additions).
fn gsums(secs: f64, d: usize) -> f64 {
    (LOOKUPS * d) as f64 / secs / 1e9
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // Non-resident: big table (256 MB at FP32 d=512) + LLC flush.
    // Resident: small table that fits L2/L3.
    let dims = [64usize, 128, 256, 512];
    let mut out = TableWriter::new(vec![
        "data type",
        "mode",
        "d=64",
        "d=128",
        "d=256",
        "d=512",
    ]);
    let mut rng = Rng::new(0x7AB1E1);
    let (warm, reps) = if quick { (0, 3) } else { (1, 7) };

    for resident in [false, true] {
        let rows = if resident { 4_096 } else { 1_000_000 };
        let mode = if resident { "resident" } else { "non-resident" };
        let mut fp32_row = Vec::new();
        let mut i8_row = Vec::new();
        let mut i4_row = Vec::new();
        for &d in &dims {
            let table = EmbeddingTable::randn_sigma(rows, d, 0.1, d as u64);
            let f8 = table.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F32);
            let f4 = table.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16);
            let (indices, lengths) = workload(rows, &mut rng);
            let args = SlsArgs::new(&indices, &lengths, rows).unwrap();
            let mut sink = vec![0.0f32; SEGMENTS * d];
            let mut flusher =
                if resident { None } else { Some(CacheFlusher::with_llc_mib(48)) };

            let mut run = |f: &mut dyn FnMut(&mut [f32])| {
                if let Some(fl) = flusher.as_mut() {
                    measure_with_setup(warm, reps, || {
                        fl.flush();
                    }, || f(&mut sink))
                } else {
                    measure(warm, reps, || f(&mut sink))
                }
            };
            let m32 = run(&mut |o| sls_f32(&table, &args, o));
            let m8 = run(&mut |o| sls_fused(&f8, &args, o));
            let m4 = run(&mut |o| sls_fused(&f4, &args, o));
            fp32_row.push(format!("{:.3}", gsums(m32.secs(), d)));
            i8_row.push(format!("{:.3}", gsums(m8.secs(), d)));
            i4_row.push(format!("{:.3}", gsums(m4.secs(), d)));
            eprintln!(
                "{mode} d={d}: fp32 {:.3} int8 {:.3} int4 {:.3} GSums/s",
                gsums(m32.secs(), d),
                gsums(m8.secs(), d),
                gsums(m4.secs(), d)
            );
        }
        let mut row = vec!["FP32".to_string(), mode.to_string()];
        row.extend(fp32_row);
        out.row(row);
        let mut row = vec!["INT8".to_string(), mode.to_string()];
        row.extend(i8_row);
        out.row(row);
        let mut row = vec!["INT4".to_string(), mode.to_string()];
        row.extend(i4_row);
        out.row(row);
    }
    println!(
        "\nTable 1 — SLS throughput (GSums/s), {LOOKUPS} pooled rows/{SEGMENTS} segments:\n{}",
        out.render()
    );
    println!(
        "Paper shape check: non-resident INT4 >= INT8 at d>=256 and INT4 >= FP32 at d>=256."
    );
}
