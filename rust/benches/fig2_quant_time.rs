//! Figure 2 reproduction: average time to 4-bit-quantize one row vector
//! vs dimension, per method (paper Appendix A; log₁₀ ms in the figure).
//!
//! The headline: HIST-BRUTE is *millions of times slower* than ASYM
//! (O(b³) model evaluations vs one min/max pass), while GREEDY stays
//! within two orders of magnitude of ASYM — cheap enough for the periodic
//! re-quantization production models need.
//!
//! ```bash
//! cargo bench --bench fig2_quant_time [-- --full]   # --full: d up to 8192
//! ```

use emberq::eval::{JsonWriter, TableWriter};
use emberq::quant::{method_by_name, KmeansQuantizer, Method};
use emberq::table::EmbeddingTable;
use emberq::util::bench::measure;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let dims: Vec<usize> =
        if full { vec![16, 64, 256, 1024, 2048, 8192] } else { vec![16, 64, 256, 1024] };
    let methods = ["ASYM", "SYM", "GSS", "ACIQ", "HIST-APPRX", "GREEDY", "KMEANS", "HIST-BRUTE"];

    let mut tw = TableWriter::new(
        std::iter::once("method".to_string())
            .chain(dims.iter().map(|d| format!("d={d}")))
            .collect::<Vec<_>>(),
    );
    let mut json = JsonWriter::new();
    json.num_array("dims", &dims.iter().map(|&d| d as f64).collect::<Vec<_>>());

    for name in methods {
        let method = method_by_name(name).unwrap();
        let mut row = vec![name.to_string()];
        let mut times = Vec::new();
        for &d in &dims {
            // HIST-BRUTE at large d: one rep only, it is the slow path by
            // design (the figure's whole point).
            let reps = match name {
                "HIST-BRUTE" => 1,
                _ if d >= 2048 => 3,
                _ => 9,
            };
            let table = EmbeddingTable::randn(1, d, d as u64 ^ 0xF2);
            let row_vals = table.row(0).to_vec();
            let m = match &method {
                Method::Uniform(q) => measure(0, reps, || q.clip(&row_vals, 4)),
                Method::Kmeans(_) => {
                    let k = KmeansQuantizer::default();
                    measure(0, reps, || k.quantize_row(&row_vals))
                }
                Method::KmeansCls(_) => unreachable!(),
            };
            let ms = m.secs() * 1e3;
            row.push(if ms < 0.001 {
                format!("{:.2}us", ms * 1e3)
            } else {
                format!("{ms:.3}ms")
            });
            times.push(ms);
            eprintln!("{name} d={d}: {ms:.4} ms/row");
        }
        json.num_array(name, &times);
        tw.row(row);
    }
    println!("\nFigure 2 — avg 4-bit quantization time per row:\n{}", tw.render());
    println!("JSON: {}", json.finish());
}
