//! Coordinator micro-benchmarks: serving throughput/latency vs shard
//! count, batch size, and table format — the ablations DESIGN.md calls
//! out for the L3 layer (batching amortization, shard scaling,
//! INT4-vs-FP32 serving).
//!
//! ```bash
//! cargo bench --bench coordinator_micro
//! ```

use emberq::coordinator::{BatchPolicy, EmbeddingServer, ServerConfig, TableSet};
use emberq::data::trace::{RequestTrace, TraceConfig};
use emberq::eval::TableWriter;
use emberq::quant::GreedyQuantizer;
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};

const NUM_TABLES: usize = 8;
const ROWS: usize = 100_000;
const DIM: usize = 64;

fn tables(kind: &str) -> TableSet {
    TableSet::new(
        (0..NUM_TABLES)
            .map(|t| {
                let tab = EmbeddingTable::randn_sigma(ROWS, DIM, 0.1, 0xC0 + t as u64);
                match kind {
                    "fp32" => AnyTable::F32(tab),
                    "int8" => AnyTable::Fused(tab.quantize_fused(
                        &GreedyQuantizer::default(),
                        8,
                        ScaleBiasDtype::F32,
                    )),
                    _ => AnyTable::Fused(tab.quantize_fused(
                        &GreedyQuantizer::default(),
                        4,
                        ScaleBiasDtype::F16,
                    )),
                }
            })
            .collect(),
    )
}

fn trace(requests: usize) -> RequestTrace {
    RequestTrace::generate(&TraceConfig {
        requests,
        num_tables: NUM_TABLES,
        rows: ROWS,
        mean_pool: 10,
        zipf_alpha: 1.05,
        seed: 0xBEEF,
    })
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let n_req = if quick { 2_000 } else { 10_000 };
    let tr = trace(n_req);

    println!("== ablation: table format (4 shards, batch 64) ==");
    let mut tw = TableWriter::new(vec!["format", "bytes", "req/s", "lookups/s", "p50", "p99"]);
    for kind in ["fp32", "int8", "int4"] {
        let set = tables(kind);
        let bytes = set.size_bytes();
        let server = EmbeddingServer::start(
            set,
            ServerConfig {
                shards: 4,
                num_shards: 0,
                queue_depth: 64,
                batch: BatchPolicy::default(),
                ..Default::default()
            },
        );
        let m = server.serve_trace(&tr);
        let (p50, _, p99) = m.latency.percentiles();
        tw.row(vec![
            kind.to_string(),
            bytes.to_string(),
            format!("{:.0}", m.throughput()),
            format!("{:.2e}", m.lookup_rate()),
            format!("{p50:.0?}"),
            format!("{p99:.0?}"),
        ]);
    }
    println!("{}", tw.render());

    println!("== ablation: worker count, table-parallel vs row-sharded (int4, batch 64) ==");
    let mut tw = TableWriter::new(vec!["workers", "table-par req/s", "row-shard req/s"]);
    for shards in [1usize, 2, 4, 8] {
        let legacy = EmbeddingServer::start(
            tables("int4"),
            ServerConfig {
                shards,
                num_shards: 0,
                queue_depth: 64,
                batch: BatchPolicy::default(),
                ..Default::default()
            },
        );
        let ml = legacy.serve_trace(&tr);
        drop(legacy);
        let sharded = EmbeddingServer::start(
            tables("int4"),
            ServerConfig {
                shards: 1,
                num_shards: shards,
                queue_depth: 64,
                batch: BatchPolicy::default(),
                ..Default::default()
            },
        );
        let ms = sharded.serve_trace(&tr);
        tw.row(vec![
            shards.to_string(),
            format!("{:.0}", ml.throughput()),
            format!("{:.0}", ms.throughput()),
        ]);
    }
    println!("{}", tw.render());

    println!("== ablation: batch size (int4, 4 shards) ==");
    let mut tw = TableWriter::new(vec!["max_batch", "req/s", "batches", "p50", "p99"]);
    for max_batch in [1usize, 8, 64, 256] {
        let server = EmbeddingServer::start(
            tables("int4"),
            ServerConfig {
                shards: 4,
                num_shards: 0,
                queue_depth: 64,
                batch: BatchPolicy { max_batch, ..Default::default() },
                ..Default::default()
            },
        );
        let m = server.serve_trace(&tr);
        let (p50, _, p99) = m.latency.percentiles();
        tw.row(vec![
            max_batch.to_string(),
            format!("{:.0}", m.throughput()),
            m.batches.to_string(),
            format!("{p50:.0?}"),
            format!("{p99:.0?}"),
        ]);
    }
    println!("{}", tw.render());
    println!("Expect: batching lifts req/s by >5x from batch 1 to 64 (dispatch amortization).");
}
