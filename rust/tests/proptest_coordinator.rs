//! Property-based tests for the coordinator: random routing/batching/
//! serving configurations must preserve the core invariants (exact
//! partitioning, order preservation, result equivalence with direct SLS).

use emberq::coordinator::{BatchPolicy, Batcher, EmbeddingServer, Router, ServerConfig, TableSet};
use emberq::data::trace::Request;
use emberq::quant::AsymQuantizer;
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

fn random_request(rng: &mut Rng, num_tables: usize, rows: usize) -> Request {
    Request {
        ids: (0..num_tables)
            .map(|_| {
                let len = rng.below(10); // may be zero
                (0..len).map(|_| rng.below(rows) as u32).collect()
            })
            .collect(),
    }
}

#[test]
fn prop_router_partitions_every_table_exactly_once() {
    let mut rng = Rng::new(0xB0);
    for _ in 0..200 {
        let tables = 1 + rng.below(40);
        let shards = 1 + rng.below(8);
        let r = Router::round_robin(tables, shards);
        let req = random_request(&mut rng, tables, 100);
        let plans = r.plan(&req);
        let mut seen = vec![0u32; tables];
        for (s, p) in plans.iter().enumerate() {
            for (t, ids) in &p.lookups {
                assert_eq!(r.shard_of(*t), s);
                assert_eq!(ids, &req.ids[*t]);
                seen[*t] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
    }
}

#[test]
fn prop_router_balance_bound() {
    // Round-robin: shard loads differ by at most one table.
    let mut rng = Rng::new(0xB1);
    for _ in 0..100 {
        let tables = 1 + rng.below(64);
        let shards = 1 + rng.below(16);
        let r = Router::round_robin(tables, shards);
        let loads: Vec<usize> = (0..shards).map(|s| r.tables_of_shard(s).len()).collect();
        let (min, max) = (loads.iter().min().unwrap(), loads.iter().max().unwrap());
        assert!(max - min <= 1, "{loads:?}");
    }
}

#[test]
fn prop_batcher_preserves_order_and_items() {
    let mut rng = Rng::new(0xB2);
    for _ in 0..50 {
        let n = 1 + rng.below(200);
        let max_batch = 1 + rng.below(32);
        let (tx, rx) = std::sync::mpsc::sync_channel(n.max(1));
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        let b = Batcher::new(
            rx,
            BatchPolicy { max_batch, max_wait: std::time::Duration::from_micros(100) },
        );
        let mut got = Vec::new();
        while let Some(batch) = b.next_batch() {
            assert!(!batch.is_empty() && batch.len() <= max_batch);
            got.extend(batch);
        }
        assert_eq!(got, (0..n).collect::<Vec<_>>());
    }
}

#[test]
fn stress_concurrent_clients_match_serial_replay() {
    // Eight client threads hammer one server with mixed batch sizes;
    // afterwards every per-request output must equal a serial replay of
    // the same request through the same server. Run on both execution
    // paths — table-parallel and row-sharded — which are deterministic
    // per request by construction (private reply channels; shard-ordered
    // merge), so equality is exact.
    for num_shards in [0usize, 3] {
        let num_tables = 4;
        let rows = 150;
        let dim = 8;
        let set = TableSet::new(
            (0..num_tables)
                .map(|t| {
                    let tab = EmbeddingTable::randn(rows, dim, 0xC0FE + t as u64);
                    AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F32))
                })
                .collect(),
        );
        let server = EmbeddingServer::start(
            set,
            ServerConfig { shards: 2, num_shards, queue_depth: 4, ..Default::default() },
        );
        // Deterministic per-client request streams.
        let client_reqs: Vec<Vec<Request>> = (0..8)
            .map(|c| {
                let mut rng = Rng::new(0xBEE5 + c as u64);
                (0..30).map(|_| random_request(&mut rng, num_tables, rows)).collect()
            })
            .collect();
        let fw = num_tables * dim;
        let results: Vec<Vec<f32>> = std::thread::scope(|scope| {
            let server = &server;
            let handles: Vec<_> = client_reqs
                .iter()
                .map(|reqs| {
                    scope.spawn(move || {
                        let mut got = vec![0.0f32; reqs.len() * fw];
                        let mut i = 0usize;
                        let mut sizes = [1usize, 3, 5, 2, 7].into_iter().cycle();
                        while i < reqs.len() {
                            let b = sizes.next().unwrap().min(reqs.len() - i);
                            server
                                .lookup_batch_into(&reqs[i..i + b], &mut got[i * fw..(i + b) * fw]);
                            i += b;
                        }
                        got
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        for (c, reqs) in client_reqs.iter().enumerate() {
            for (i, req) in reqs.iter().enumerate() {
                let serial = server.lookup(req);
                assert_eq!(
                    &results[c][i * fw..(i + 1) * fw],
                    serial.as_slice(),
                    "num_shards={num_shards} client {c} request {i}"
                );
            }
        }
    }
}

#[test]
fn prop_server_equals_sequential_reference() {
    // Whatever the shard count, queue depth, or batch grouping, the
    // server must return exactly what direct TableSet pooling returns.
    let mut rng = Rng::new(0xB3);
    for case in 0..20 {
        let num_tables = 1 + rng.below(6);
        let rows = 20 + rng.below(100);
        let dim = [4usize, 8, 16][rng.below(3)];
        let shards = 1 + rng.below(4);
        let mk_tables = || -> Vec<AnyTable> {
            (0..num_tables)
                .map(|t| {
                    let tab = EmbeddingTable::randn(rows, dim, 7000 + case * 100 + t as u64);
                    AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F32))
                })
                .collect()
        };
        let reference = TableSet::new(mk_tables());
        let server = EmbeddingServer::start(
            TableSet::new(mk_tables()),
            ServerConfig { shards, queue_depth: 1 + rng.below(16), ..Default::default() },
        );
        let reqs: Vec<Request> =
            (0..1 + rng.below(20)).map(|_| random_request(&mut rng, num_tables, rows)).collect();
        let mut out = vec![0.0f32; reqs.len() * num_tables * dim];
        server.lookup_batch_into(&reqs, &mut out);
        for (s, req) in reqs.iter().enumerate() {
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; dim];
                reference.pool(t, ids, &mut want);
                let base = s * num_tables * dim;
                let got = &out[base + t * dim..base + (t + 1) * dim];
                assert_eq!(got, want.as_slice(), "case {case} slot {s} table {t}");
            }
        }
    }
}
