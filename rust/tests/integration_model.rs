//! Integration: the full train → quantize → evaluate pipeline (the
//! paper's §5 experiment at test scale).

use emberq::data::{CriteoConfig, SyntheticCriteo};
use emberq::model::{Dlrm, DlrmConfig, QuantizedDlrm, Trainer, TrainerConfig};
use emberq::quant::{AsymQuantizer, GreedyQuantizer, SymQuantizer};
use emberq::table::{CodebookKind, ScaleBiasDtype};

fn train_model(dim: usize, steps: usize) -> (Dlrm, Vec<emberq::data::ClickBatch>) {
    let dcfg = CriteoConfig {
        num_sparse: 4,
        rows_per_table: 500,
        ..Default::default()
    };
    let mcfg = DlrmConfig {
        num_tables: 4,
        rows_per_table: 500,
        dim,
        dense_dim: dcfg.dense_dim,
        hidden: vec![64, 64],
        seed: 77,
    };
    let mut model = Dlrm::new(mcfg);
    let mut data = SyntheticCriteo::train(dcfg.clone());
    Trainer::new(TrainerConfig { batch: 100, steps, log_every: steps, ..Default::default() })
        .train(&mut model, &mut data);
    let mut eval = SyntheticCriteo::eval(dcfg);
    let batches = (0..6).map(|_| eval.next_batch(500)).collect();
    (model, batches)
}

fn mean_loss(losses: impl Iterator<Item = f64>) -> f64 {
    let v: Vec<f64> = losses.collect();
    v.iter().sum::<f64>() / v.len() as f64
}

#[test]
fn training_learns_then_quantization_stays_neutral() {
    let (model, batches) = train_model(16, 500);
    let fp32 = mean_loss(batches.iter().map(|b| model.eval_logloss(b)));
    // The model must beat chance (labels ~46% positive -> logloss ~0.69).
    assert!(fp32 < 0.67, "model did not learn: {fp32}");

    // 4-bit GREEDY: Table-3 neutrality (<1% relative delta at d=16).
    let q =
        QuantizedDlrm::from_uniform(&model, &GreedyQuantizer::default(), 4, ScaleBiasDtype::F16);
    let ql = mean_loss(batches.iter().map(|b| q.eval_logloss(b)));
    assert!(
        (ql - fp32).abs() / fp32 < 0.01,
        "greedy 4-bit not neutral: {fp32} -> {ql}"
    );

    // 8-bit ASYM: even tighter.
    let q8 = QuantizedDlrm::from_uniform(&model, &AsymQuantizer, 8, ScaleBiasDtype::F32);
    let ql8 = mean_loss(batches.iter().map(|b| q8.eval_logloss(b)));
    assert!((ql8 - fp32).abs() / fp32 < 0.002, "asym 8-bit drifted: {fp32} -> {ql8}");
}

#[test]
fn method_quality_ordering_survives_to_model_loss() {
    // Row-wise GREEDY must degrade the model less than whole-table-clip
    // quantization (the Figure-1 TABLE baseline) — the robust version of
    // Table 3's ordering story. (GREEDY-vs-SYM deltas are noise-level at
    // this scale because near-init embeddings stay zero-centered; the
    // feature-level ordering is asserted in integration_quant.rs.)
    let (model, batches) = train_model(32, 400);
    let fp32 = mean_loss(batches.iter().map(|b| model.eval_logloss(b)));
    let deg = |l: f64| (l - fp32).abs();
    let greedy = mean_loss(batches.iter().map(|b| {
        QuantizedDlrm::from_uniform(&model, &GreedyQuantizer::default(), 4, ScaleBiasDtype::F32)
            .eval_logloss(b)
    }));
    // Whole-table clip: one scale/bias shared by all rows of each table.
    let tablewise = emberq::model::QuantizedDlrm {
        cfg: model.cfg.clone(),
        tables: emberq::model::QuantTables::Fused(
            model
                .tables
                .iter()
                .map(|t| {
                    t.quantize_fused_tablewise(&SymQuantizer, 4, ScaleBiasDtype::F32)
                })
                .collect(),
        ),
        mlp: model.mlp.clone(),
    };
    let tb = mean_loss(batches.iter().map(|b| tablewise.eval_logloss(b)));
    assert!(
        deg(greedy) < deg(tb),
        "greedy deg {} vs tablewise deg {}",
        deg(greedy),
        deg(tb)
    );
    // And 4-bit GREEDY stays neutral (<1% relative).
    assert!(deg(greedy) / fp32 < 0.01, "greedy not neutral: {}", deg(greedy) / fp32);
}

#[test]
fn kmeans_exact_at_d16_model_level() {
    // d=16 rows have <=16 distinct values: KMEANS reproduces the model
    // bit-exactly (paper Table 3 "-" cells become identical loss).
    let (model, batches) = train_model(16, 200);
    let q = QuantizedDlrm::from_codebook(&model, CodebookKind::Rowwise, ScaleBiasDtype::F32);
    for b in &batches {
        assert!((q.eval_logloss(b) - model.eval_logloss(b)).abs() < 1e-12);
    }
}

#[test]
fn size_ratios_at_model_level_match_paper() {
    let (model, _) = train_model(32, 50);
    // GREEDY(FP16) at d=32: paper says 15.62%.
    let q =
        QuantizedDlrm::from_uniform(&model, &GreedyQuantizer::default(), 4, ScaleBiasDtype::F16);
    let ratio = q.tables_bytes() as f64 / model.tables_bytes() as f64;
    assert!((ratio - 0.15625).abs() < 1e-6, "ratio {ratio}");
    // KMEANS(FP16) at d=32: paper says 37.50%.
    let qk = QuantizedDlrm::from_codebook(&model, CodebookKind::Rowwise, ScaleBiasDtype::F16);
    let ratio = qk.tables_bytes() as f64 / model.tables_bytes() as f64;
    assert!((ratio - 0.375).abs() < 1e-6, "kmeans ratio {ratio}");
}

#[test]
fn loss_curve_monotone_ish() {
    // The training loss curve must show learning (first window > last).
    let dcfg = CriteoConfig { num_sparse: 3, rows_per_table: 300, ..Default::default() };
    let mcfg = DlrmConfig {
        num_tables: 3,
        rows_per_table: 300,
        dim: 8,
        dense_dim: dcfg.dense_dim,
        hidden: vec![32],
        seed: 5,
    };
    let mut model = Dlrm::new(mcfg);
    let mut data = SyntheticCriteo::train(dcfg);
    let report = Trainer::new(TrainerConfig {
        batch: 100,
        steps: 400,
        log_every: 100,
        ..Default::default()
    })
    .train(&mut model, &mut data);
    assert!(report.loss_curve.len() >= 4);
    assert!(report.final_loss < report.loss_curve[0].1);
}
