//! Adaptive shard load management, end to end: work stealing under a
//! skewed workload, the interval-driven runtime rebalancer, and panic
//! containment on the serving path.

use std::time::Duration;

use emberq::coordinator::{EmbeddingServer, ServerConfig, TableCatalog, TableSet};
use emberq::data::trace::Request;
use emberq::quant::GreedyQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};

fn fused_set(num_tables: usize, rows: usize, dim: usize, seed: u64) -> TableSet {
    TableSet::new(
        (0..num_tables)
            .map(|t| {
                let tab = EmbeddingTable::randn(rows, dim, seed + 7 * t as u64);
                AnyTable::Fused(tab.quantize_fused(
                    &GreedyQuantizer::default(),
                    4,
                    ScaleBiasDtype::F16,
                ))
            })
            .collect(),
    )
}

/// A skewed request: every table touched, the hot table pooling far more
/// rows than the rest.
fn skewed_request(num_tables: usize, rows: usize, hot: usize, i: u32) -> Request {
    Request {
        ids: (0..num_tables)
            .map(|t| {
                let pool: u32 = if t == hot { 48 } else { 2 };
                (0..pool).map(|j| ((i * 31 + j * 13 + t as u32) % rows as u32)).collect()
            })
            .collect(),
    }
}

#[test]
fn stealing_absorbs_whole_table_skew() {
    // Four whole tables over four shards, one of them dominating the
    // traffic: with stealing on, the hot shard's queue must drain
    // through its peers and results must stay bit-exact.
    let reference = fused_set(4, 96, 8, 0xAD01);
    let engine = ShardedEngine::start(
        fused_set(4, 96, 8, 0xAD01),
        &ShardConfig {
            num_shards: 4,
            small_table_rows: usize::MAX,
            steal: true,
            ..Default::default()
        },
    );
    let reqs: Vec<Request> = (0..600).map(|i| skewed_request(4, 96, 0, i)).collect();
    let fw = engine.feature_width();
    let mut out = vec![0.0f32; reqs.len() * fw];
    for _attempt in 0..5 {
        engine.lookup_batch_into(&reqs, &mut out);
        if engine.steal_count() > 0 {
            break;
        }
    }
    assert!(engine.steal_count() > 0, "peers never stole from the hot shard");
    for (slot, req) in reqs.iter().enumerate().step_by(97) {
        for (t, ids) in req.ids.iter().enumerate() {
            let mut want = vec![0.0f32; 8];
            reference.pool(t, ids, &mut want);
            assert_eq!(
                &out[slot * fw + t * 8..slot * fw + (t + 1) * 8],
                want.as_slice(),
                "slot {slot} table {t}"
            );
        }
    }
}

#[test]
fn background_rebalancer_replicates_the_hottest_table() {
    // The satellite acceptance check: drive a skewed load, wait at least
    // one interval, and the rebalancer must have added replicas for the
    // hottest table — with routing still valid against the catalog and
    // results unchanged to the bit.
    let reference = fused_set(3, 64, 8, 0xAD02);
    let catalog = TableCatalog::of(&reference);
    let engine = ShardedEngine::start(
        fused_set(3, 64, 8, 0xAD02),
        &ShardConfig {
            num_shards: 3,
            small_table_rows: usize::MAX,
            steal: true,
            rebalance_interval: Some(Duration::from_millis(20)),
            ..Default::default()
        },
    );
    let hot = 1usize;
    let probe = skewed_request(3, 64, hot, 9);
    let before = engine.lookup(&probe);
    // Drive load, then give the 20 ms rebalancer a few intervals.
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    loop {
        for i in 0..50u32 {
            let _ = engine.lookup(&skewed_request(3, 64, hot, i));
        }
        if engine.rebalance_stats().rebalances > 0 || std::time::Instant::now() > deadline {
            break;
        }
        std::thread::sleep(Duration::from_millis(25));
    }
    let stats = engine.rebalance_stats();
    assert!(stats.rebalances > 0, "rebalancer never ticked with load observed");
    assert!(stats.replicas_added > 0, "no replica added for the hot table");
    assert_eq!(
        engine.replica_shards(hot),
        vec![0, 1, 2],
        "hottest table must be replicated everywhere"
    );
    engine.validate_routing(&catalog).expect("routing valid after runtime re-replication");
    assert!(engine.replicated_bytes() > 0);
    assert_eq!(engine.lookup(&probe), before, "results survive re-replication bit-for-bit");
}

/// Drive `lookups` pooled lookups at table `t` (2 ids each).
fn drive(engine: &ShardedEngine, num_tables: usize, rows: usize, t: usize, lookups: u32) {
    for i in 0..lookups / 2 {
        let ids = (0..num_tables)
            .map(|tt| {
                if tt == t {
                    vec![i % rows as u32, (i * 7 + 1) % rows as u32]
                } else {
                    Vec::new()
                }
            })
            .collect();
        let _ = engine.lookup(&Request { ids });
    }
}

#[test]
fn decayed_windows_do_not_thrash_bursty_replicas() {
    // Table 0 is bursty (heavy traffic every other rebalance tick);
    // table 1 trickles steadily. Under the old last-tick-window ranking
    // every gap tick ranked table 0 stone cold — its replicas were
    // retired and the next burst re-copied the full table, every other
    // tick. The exponential-decay windows keep half the burst's heat
    // across the gap, so after the first replication the placement must
    // never churn again.
    let engine = ShardedEngine::start(
        fused_set(2, 64, 8, 0xAD06),
        &ShardConfig {
            num_shards: 2,
            small_table_rows: usize::MAX, // whole tables: replication candidates
            ..Default::default()
        },
    );
    // Burst tick: table 0 runs hot and gets replicated.
    drive(&engine, 2, 64, 0, 300);
    drive(&engine, 2, 64, 1, 10);
    assert!(engine.rebalance_once());
    assert_eq!(engine.replica_shards(0).len(), 2, "burst table replicated");
    let after_first = engine.rebalance_stats();
    assert_eq!(after_first.replicas_added, 1);
    // Alternate gap/burst ticks. Decayed heat (300 → 150 → 375 → ...)
    // keeps table 0 the hottest whole table throughout, so no tick may
    // retire it, re-add it, or replicate the trickle table instead.
    for round in 0..6 {
        if round % 2 == 1 {
            drive(&engine, 2, 64, 0, 300); // burst is back
        }
        drive(&engine, 2, 64, 1, 10); // the steady trickle
        engine.rebalance_once();
        assert_eq!(
            engine.replica_shards(0).len(),
            2,
            "round {round}: bursty table lost its replica on a gap tick"
        );
        assert_eq!(engine.replica_shards(1).len(), 1, "round {round}");
    }
    let stats = engine.rebalance_stats();
    assert_eq!(
        stats.replicas_added, after_first.replicas_added,
        "no re-copies: decay must absorb the bursts"
    );
    assert_eq!(stats.replicas_retired, 0, "no retirements across burst gaps");
}

#[test]
fn fully_decayed_heat_still_retires_replicas() {
    // The flip side of no-thrash: once a table goes genuinely cold (its
    // decayed heat reaches zero while other traffic continues), the
    // quiet-tick backstop must still reclaim the replicas.
    let engine = ShardedEngine::start(
        fused_set(2, 64, 8, 0xAD07),
        &ShardConfig {
            num_shards: 2,
            small_table_rows: usize::MAX,
            ..Default::default()
        },
    );
    drive(&engine, 2, 64, 0, 200);
    assert!(engine.rebalance_once());
    assert_eq!(engine.replica_shards(0).len(), 2);
    // Shift all traffic to table 1: table 0's heat halves every tick and
    // table 1 takes over the hot slot, retiring table 0's replica.
    let mut retired = false;
    for _ in 0..16 {
        drive(&engine, 2, 64, 1, 120);
        engine.rebalance_once();
        if engine.replica_shards(0).len() == 1 {
            retired = true;
            break;
        }
    }
    assert!(retired, "a genuinely cold table must eventually lose its replica");
    assert_eq!(engine.replica_shards(1).len(), 2, "the new hot table took over");
}

#[test]
fn server_survives_worker_panic_and_reports_it() {
    // A malformed id slipped past validation (engine called directly via
    // an unvalidated request) panics inside a worker. The server must
    // answer, count the panic, and keep the stats path alive — the
    // poison-tolerant locking regression test at the integration layer.
    let set = fused_set(2, 32, 8, 0xAD03);
    let server = EmbeddingServer::start(
        set,
        ServerConfig { num_shards: 2, ..Default::default() },
    );
    let bad = Request { ids: vec![vec![31, 77777], vec![1]] };
    let out = server.lookup(&bad);
    assert_eq!(out.len(), 16);
    assert_eq!(&out[0..8], &[0.0; 8], "panicked segment is zeroed, not garbage");
    let stats = server.shard_stats().expect("sharded");
    assert_eq!(stats.iter().map(|s| s.panics).sum::<u64>(), 1);
    // Stats text (what the TCP stats frame serves) still renders.
    let text = server.stats_text();
    assert!(text.contains("adaptive:"), "{text}");
    // And a healthy replay still accounts exactly.
    let ok = Request { ids: vec![vec![0, 31], vec![5]] };
    let first = server.lookup(&ok);
    assert_eq!(server.lookup(&ok), first);
    assert_eq!(server.submit(&ok), first, "intake path agrees bitwise");
}

#[test]
fn adaptive_serving_stays_exact_under_trace_replay() {
    // Full server stack with stealing + rebalancing against a replayed
    // trace: metrics account for every lookup and the per-shard stats
    // include the steal counters.
    use emberq::data::trace::{RequestTrace, TraceConfig};
    let set = fused_set(4, 256, 8, 0xAD04);
    let server = EmbeddingServer::start(
        set,
        ServerConfig {
            num_shards: 4,
            steal: true,
            rebalance_interval: Some(Duration::from_millis(10)),
            ..Default::default()
        },
    );
    let trace = RequestTrace::generate(&TraceConfig {
        requests: 200,
        num_tables: 4,
        rows: 256,
        mean_pool: 8,
        zipf_alpha: 1.2,
        seed: 0xAD05,
    });
    let m = server.serve_trace(&trace);
    assert_eq!(m.requests, 200);
    assert_eq!(m.lookups as usize, trace.total_lookups());
    let shard_lookups: u64 = m.per_shard.iter().map(|s| s.lookups).sum();
    assert_eq!(shard_lookups, m.lookups);
    server.validate_routing().expect("routing stays valid under replay");
    // Replay twice: bit-identical (stealing and rebalancing are
    // correctness-invisible).
    let mut a = vec![0.0f32; 32];
    let mut b = vec![1.0f32; 32];
    server.lookup_batch_into(&trace.requests[..1], &mut a);
    let _ = server.rebalance_once();
    server.lookup_batch_into(&trace.requests[..1], &mut b);
    assert_eq!(a, b);
}
