//! Connection-storm chaos scenario for the TCP fronts.
//!
//! A deterministic-shape storm (seeded `Rng`/`Zipf`, wall-clock-free
//! decisions) hammers each front with everything a production accept
//! loop sees at once:
//!
//! * **churners** — connect, fire a couple of lookups, disconnect, loop;
//! * **idlers** — connect and go silent (the reactor's sweep and the
//!   blocking front's socket timeouts exist for these);
//! * **vandals** — send garbage or half frames and vanish;
//! * **workers** — long-lived connections streaming Zipf-shaped lookups
//!   whose replies must stay **bit-exact** against an unsharded oracle
//!   server the whole time.
//!
//! The storm passes when every worker lookup matched the oracle, the
//! front still serves a fresh connection afterwards, and the admission
//! counters saw no sheds (nothing here is admission-limited — a shed
//! would mean the storm corrupted the control state).

use std::io::Write;
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;

use emberq::coordinator::{
    EmbeddingServer, ReactorFront, ServerConfig, TableSet, TcpClient, TcpFront,
};
use emberq::data::trace::Request;
use emberq::quant::GreedyQuantizer;
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::{Rng, Zipf};

const TABLES: usize = 3;
const ROWS: usize = 64;
const DIM: usize = 8;

fn quantized_tables(seed: u64) -> Vec<AnyTable> {
    (0..TABLES)
        .map(|t| {
            let tab = EmbeddingTable::randn(ROWS, DIM, seed + t as u64);
            AnyTable::Fused(tab.quantize_fused(
                &GreedyQuantizer::default(),
                4,
                ScaleBiasDtype::F16,
            ))
        })
        .collect()
}

/// Zipf-shaped pooled lookup: a few hot rows dominate, like real
/// embedding traffic.
fn storm_request(rng: &mut Rng, zipf: &Zipf) -> Vec<Vec<u32>> {
    (0..TABLES)
        .map(|_| {
            let pool = 1 + rng.below(6);
            (0..pool).map(|_| zipf.sample(rng) as u32).collect()
        })
        .collect()
}

fn run_storm(addr: SocketAddr, oracle: &Arc<EmbeddingServer>) {
    // Idlers: open sockets that never speak; they must not wedge an
    // accept slot or a worker thread for anyone else. Held here so
    // they stay open for the entire storm (the scope joins below).
    let idlers: Vec<TcpStream> = (0..8).map(|_| TcpStream::connect(addr).unwrap()).collect();
    std::thread::scope(|sc| {
        // Workers: sustained bit-exact traffic through the whole storm.
        for w in 0..4u64 {
            let oracle = Arc::clone(oracle);
            sc.spawn(move || {
                let mut rng = Rng::new(0x5708 + w);
                let zipf = Zipf::new(ROWS, 1.1);
                let mut client = TcpClient::connect(addr).unwrap();
                for i in 0..60 {
                    let ids = storm_request(&mut rng, &zipf);
                    let got = client.lookup(&ids).unwrap();
                    let want = oracle.lookup(&Request { ids });
                    assert_eq!(got, want, "worker {w} lookup {i} diverged");
                }
            });
        }
        // Churners: connect, a couple of lookups, disconnect, repeat.
        for c in 0..3u64 {
            let oracle = Arc::clone(oracle);
            sc.spawn(move || {
                let mut rng = Rng::new(0xC0C0 + c);
                let zipf = Zipf::new(ROWS, 1.1);
                for _ in 0..15 {
                    let mut client = TcpClient::connect(addr).unwrap();
                    for _ in 0..2 {
                        let ids = storm_request(&mut rng, &zipf);
                        let got = client.lookup(&ids).unwrap();
                        assert_eq!(got, oracle.lookup(&Request { ids }), "churner diverged");
                    }
                }
            });
        }
        // Vandals: garbage headers and half frames, then vanish.
        for v in 0..3u64 {
            sc.spawn(move || {
                let mut rng = Rng::new(0xBAD + v);
                for _ in 0..10 {
                    let mut s = TcpStream::connect(addr).unwrap();
                    match rng.below(3) {
                        0 => {
                            // Absurd table count: earns an error frame.
                            let _ = s.write_all(&u32::MAX.to_le_bytes());
                        }
                        1 => {
                            // Half a frame, then silence.
                            let _ = s.write_all(&3u32.to_le_bytes());
                            let _ = s.write_all(&1u32.to_le_bytes());
                        }
                        _ => {
                            // Random bytes.
                            let junk: Vec<u8> =
                                (0..13).map(|_| rng.next_u64() as u8).collect();
                            let _ = s.write_all(&junk);
                        }
                    }
                    let _ = s.shutdown(Shutdown::Write);
                }
            });
        }
    });
    drop(idlers);
}

fn assert_healthy_after(addr: SocketAddr, server: &EmbeddingServer) {
    let mut c = TcpClient::connect(addr).unwrap();
    assert_eq!(c.lookup(&[vec![1], vec![2], vec![3]]).unwrap().len(), TABLES * DIM);
    let snap = server.admission().snapshot();
    assert_eq!(snap.shed_total(), 0, "unconfigured admission must never shed: {snap:?}");
    // 4 workers x 60 + 3 churners x 15 x 2 = 330 admitted lookups, plus
    // the health check; vandal junk never reaches admission.
    assert!(snap.admitted >= 331, "{snap:?}");
    let stats = c.stats().unwrap();
    assert!(stats.contains("admission:"), "{stats}");
}

#[test]
fn connection_storm_reactor_front_stays_bit_exact() {
    let server = Arc::new(EmbeddingServer::start(
        TableSet::new(quantized_tables(4400)),
        ServerConfig { num_shards: 2, ..Default::default() },
    ));
    // The oracle serves the same tables unsharded, straight through the
    // table-parallel pool — no reactor, no batcher coalescing races.
    let oracle = Arc::new(EmbeddingServer::start(
        TableSet::new(quantized_tables(4400)),
        ServerConfig::default(),
    ));
    let front = ReactorFront::start(Arc::clone(&server), "127.0.0.1:0").unwrap();
    run_storm(front.addr(), &oracle);
    assert_healthy_after(front.addr(), &server);
}

#[test]
fn connection_storm_blocking_front_stays_bit_exact() {
    let server = Arc::new(EmbeddingServer::start(
        TableSet::new(quantized_tables(4400)),
        ServerConfig { num_shards: 2, ..Default::default() },
    ));
    let oracle = Arc::new(EmbeddingServer::start(
        TableSet::new(quantized_tables(4400)),
        ServerConfig::default(),
    ));
    let front = TcpFront::start(Arc::clone(&server), "127.0.0.1:0").unwrap();
    run_storm(front.addr(), &oracle);
    assert_healthy_after(front.addr(), &server);
}
