//! Property tests for tiered slice storage: spill → reload → serve must
//! be bit-exact vs. fully-resident serving for every table format
//! (fp32, int4/f16, int8, rowwise codebook, two-tier codebook), across
//! shard counts, placement regimes, and mid-stream demote/promote churn
//! (hand-rolled property loops — the crate builds offline with no
//! test-framework dependencies).

use emberq::coordinator::TableSet;
use emberq::data::trace::Request;
use emberq::quant::AsymQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

/// Deterministic table builder so the reference set and the engine's set
/// hold identical contents (same idiom as proptest_shard.rs).
fn build_tables(
    seed: u64,
    fmt: usize,
    num_tables: usize,
    rows: usize,
    dim: usize,
) -> Vec<AnyTable> {
    (0..num_tables)
        .map(|t| {
            let tab = EmbeddingTable::randn(rows, dim, seed + 31 * t as u64);
            match fmt {
                0 => AnyTable::F32(tab),
                1 => AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)),
                2 => AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F32)),
                3 => AnyTable::Codebook(
                    tab.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32),
                ),
                _ => {
                    let k = (1 + t % 3).min(rows);
                    AnyTable::Codebook(
                        tab.quantize_codebook(CodebookKind::TwoTier { k }, ScaleBiasDtype::F16),
                    )
                }
            }
        })
        .collect()
}

fn random_ids(rng: &mut Rng, rows: usize) -> Vec<u32> {
    let len = rng.below(10); // may be empty
    (0..len).map(|_| rng.below(rows) as u32).collect()
}

#[test]
fn prop_spill_reload_serve_is_bit_exact_every_format() {
    // Budget around a third of the carved bytes: slices churn between
    // tiers constantly. Every lookup must equal the unsharded pool bit
    // for bit — including right after `spill_all` (everything demoted
    // mid-stream) and after rebalance passes.
    let mut rng = Rng::new(0x5709);
    for case in 0..60usize {
        let fmt = case % 5;
        let shards = 1 + (case % 4);
        let num_tables = 1 + rng.below(3);
        let rows = 8 + rng.below(80);
        let dim = [4usize, 8, 16][rng.below(3)];
        // Cover both placement regimes: whole tables and row-wise chunks.
        let small_table_rows = if case % 2 == 0 { usize::MAX } else { 0 };
        let seed = 0xD0_0000 + case as u64 * 101;
        let reference = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
        let logical = reference.size_bytes();
        let engine = ShardedEngine::start(
            TableSet::new(build_tables(seed, fmt, num_tables, rows, dim)),
            &ShardConfig {
                num_shards: shards,
                small_table_rows,
                resident_budget: Some((logical / 3).max(1)),
                ..Default::default()
            },
        );
        let fw = engine.feature_width();
        for round in 0..6 {
            // Mid-stream churn: demote everything every other round, and
            // run a rebalance pass (decay tick + possible re-replication)
            // on round 3.
            if round % 2 == 1 {
                engine.spill_all().expect("demote-all must succeed");
            }
            if round == 3 {
                let _ = engine.rebalance_once();
            }
            let reqs: Vec<Request> = (0..2)
                .map(|_| Request {
                    ids: (0..num_tables).map(|_| random_ids(&mut rng, rows)).collect(),
                })
                .collect();
            let mut out = vec![1.0f32; reqs.len() * fw]; // stale garbage must vanish
            engine.lookup_batch_into(&reqs, &mut out);
            for (slot, req) in reqs.iter().enumerate() {
                for (t, ids) in req.ids.iter().enumerate() {
                    let mut want = vec![0.0f32; dim];
                    reference.pool(t, ids, &mut want);
                    assert_eq!(
                        &out[slot * fw + t * dim..slot * fw + (t + 1) * dim],
                        want.as_slice(),
                        "case {case} round {round} slot {slot} table {t} \
                         (fmt {fmt}, {shards} shards, rows {rows})"
                    );
                }
            }
        }
        let stats = engine.store_stats().expect("tiered storage active");
        assert_eq!(stats.spill_errors, 0, "case {case}");
        assert!(stats.demotions > 0, "case {case}: churn must demote");
        // Byte reconciliation: resident + spilled is the sum of every
        // cell's bytes, so it covers the carved total exactly for
        // fp32/fused/rowwise-codebook slices (all linear in rows). A
        // two-tier codebook chunk additionally keeps the K small shared
        // codebooks (~100 B each) plus sub-byte cluster-id rounding —
        // bound that epsilon instead of demanding equality.
        let resident: usize = engine.shard_bytes().iter().sum();
        let covered = resident + engine.spilled_bytes();
        let carved = logical + engine.replicated_bytes();
        if fmt != 4 {
            assert_eq!(covered, carved, "case {case} (fmt {fmt})");
        } else {
            assert!(covered >= carved, "case {case}");
            assert!(
                covered <= carved + shards * num_tables * 256,
                "case {case}: two-tier epsilon blew up ({covered} vs {carved})"
            );
        }
    }
}

#[test]
fn prop_budget_is_always_honored_at_rest() {
    // After every batch (transitions quiesced), RAM-resident bytes must
    // sit at or under the budget, for budgets from "one slice" up to
    // "almost everything".
    let mut rng = Rng::new(0x570A);
    for case in 0..20usize {
        let shards = 1 + (case % 3);
        let rows = 30 + rng.below(60);
        let seed = 0xE0_0000 + case as u64 * 7;
        let reference = TableSet::new(build_tables(seed, 1, 3, rows, 8));
        let logical = reference.size_bytes();
        let budget = (logical * (1 + case % 4) / 4).max(1);
        let engine = ShardedEngine::start(
            TableSet::new(build_tables(seed, 1, 3, rows, 8)),
            &ShardConfig {
                num_shards: shards,
                small_table_rows: usize::MAX,
                resident_budget: Some(budget),
                ..Default::default()
            },
        );
        for i in 0..8 {
            let req = Request {
                ids: (0..3).map(|_| random_ids(&mut rng, rows)).collect(),
            };
            let got = engine.lookup(&req);
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; 8];
                reference.pool(t, ids, &mut want);
                assert_eq!(&got[t * 8..(t + 1) * 8], want.as_slice(), "case {case} req {i}");
            }
            let resident: usize = engine.shard_bytes().iter().sum();
            assert!(
                resident <= budget,
                "case {case} req {i}: resident {resident} over budget {budget}"
            );
        }
    }
}
