//! Cross-language golden tests: replay the inputs from
//! `python/tests/golden/quant_golden.txt` (checked in; regenerate with
//! `python -m compile.quant_ref --out tests/golden/quant_golden.txt`
//! from `python/`) through the Rust quantizers and check agreement with
//! the independent Python implementations. If the fixture is absent the
//! tests *skip* with a message instead of failing — the gate must stay
//! hermetic on checkouts without the Python tree.
//!
//! Contract:
//! * ASYM clips match exactly (both are min/max);
//! * GREEDY may settle on a different equal-quality local optimum under
//!   f32 tie-breaking, so we require the *loss* to match within 2% (and
//!   never exceed the Python ASYM loss);
//! * KMEANS codebook MSE matches within 2%.

use emberq::quant::{
    quant_sq_error, AsymQuantizer, Clip, GreedyQuantizer, KmeansQuantizer, Quantizer,
};

struct GoldenCase {
    d: usize,
    input: Vec<f32>,
    asym: (f32, f32),
    greedy: (f32, f32),
    greedy_loss: f64,
    kmeans_mse: f64,
}

fn parse_golden(text: &str) -> Vec<GoldenCase> {
    let mut cases = Vec::new();
    let mut cur: Option<GoldenCase> = None;
    for line in text.lines() {
        if line.starts_with('#') || line.trim().is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        match parts.next().unwrap() {
            "case" => {
                if let Some(c) = cur.take() {
                    cases.push(c);
                }
                let d = line
                    .split("d=")
                    .nth(1)
                    .unwrap()
                    .split_whitespace()
                    .next()
                    .unwrap()
                    .parse()
                    .unwrap();
                cur = Some(GoldenCase {
                    d,
                    input: Vec::new(),
                    asym: (0.0, 0.0),
                    greedy: (0.0, 0.0),
                    greedy_loss: 0.0,
                    kmeans_mse: 0.0,
                });
            }
            "input" => {
                let c = cur.as_mut().unwrap();
                c.input = parts
                    .next()
                    .unwrap()
                    .split(',')
                    .map(|v| v.parse().unwrap())
                    .collect();
            }
            "asym" => {
                let c = cur.as_mut().unwrap();
                c.asym = (
                    parts.next().unwrap().parse().unwrap(),
                    parts.next().unwrap().parse().unwrap(),
                );
            }
            "greedy" => {
                let c = cur.as_mut().unwrap();
                c.greedy = (
                    parts.next().unwrap().parse().unwrap(),
                    parts.next().unwrap().parse().unwrap(),
                );
                assert_eq!(parts.next(), Some("loss"));
                c.greedy_loss = parts.next().unwrap().parse().unwrap();
            }
            "kmeans_mse" => {
                cur.as_mut().unwrap().kmeans_mse = parts.next().unwrap().parse().unwrap();
            }
            other => panic!("unknown golden line: {other}"),
        }
    }
    if let Some(c) = cur {
        cases.push(c);
    }
    cases
}

/// Load the golden cases, or `None` (with an explanatory note on stderr)
/// when the fixture isn't present in this checkout.
fn load_cases() -> Option<Vec<GoldenCase>> {
    // The crate lives in `rust/`; the fixture ships with the Python tree.
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("../python/tests/golden/quant_golden.txt");
    let text = match std::fs::read_to_string(&path) {
        Ok(text) => text,
        Err(e) => {
            eprintln!(
                "skipping golden cross-lang test: {} unreadable ({e}) — regenerate with \
                 `python -m compile.quant_ref --out tests/golden/quant_golden.txt` from python/",
                path.display()
            );
            return None;
        }
    };
    let cases = parse_golden(&text);
    assert_eq!(cases.len(), 15, "expected 15 golden cases");
    Some(cases)
}

#[test]
fn asym_clips_match_exactly() {
    let Some(cases) = load_cases() else { return };
    for (i, c) in cases.iter().enumerate() {
        assert_eq!(c.input.len(), c.d, "case {i} input length");
        let clip = AsymQuantizer.clip(&c.input, 4);
        assert_eq!(clip.xmin, c.asym.0, "case {i} xmin");
        assert_eq!(clip.xmax, c.asym.1, "case {i} xmax");
    }
}

#[test]
fn greedy_losses_match_python() {
    let Some(cases) = load_cases() else { return };
    for (i, c) in cases.iter().enumerate() {
        let clip = GreedyQuantizer::default().clip(&c.input, 4);
        let rust_loss = quant_sq_error(&c.input, clip, 4);
        let rel = (rust_loss - c.greedy_loss).abs() / c.greedy_loss.max(1e-12);
        assert!(
            rel < 0.02,
            "case {i}: rust loss {rust_loss} vs python {} (rel {rel})",
            c.greedy_loss
        );
        // And the Python clip evaluated by Rust arithmetic is no better
        // than 2% below the Rust result either (same optimum family).
        let py_clip = Clip { xmin: c.greedy.0, xmax: c.greedy.1 };
        let py_loss_rust = quant_sq_error(&c.input, py_clip, 4);
        assert!(
            rust_loss <= py_loss_rust * 1.02,
            "case {i}: rust {rust_loss} much worse than python clip {py_loss_rust}"
        );
    }
}

#[test]
fn kmeans_mse_matches_python() {
    let Some(cases) = load_cases() else { return };
    for (i, c) in cases.iter().enumerate() {
        let (cb, codes) = KmeansQuantizer::default().quantize_row(&c.input);
        let mse: f64 = c
            .input
            .iter()
            .zip(&codes)
            .map(|(&x, &code)| ((x - cb[code as usize]) as f64).powi(2))
            .sum();
        if c.kmeans_mse == 0.0 {
            assert!(mse < 1e-12, "case {i}: expected exact, got {mse}");
        } else {
            let rel = (mse - c.kmeans_mse).abs() / c.kmeans_mse;
            assert!(
                rel < 0.02,
                "case {i}: rust kmeans mse {mse} vs python {} (rel {rel})",
                c.kmeans_mse
            );
        }
    }
}

#[test]
fn greedy_beats_asym_on_every_golden_case() {
    let Some(cases) = load_cases() else { return };
    for (i, c) in cases.iter().enumerate() {
        let asym_clip = Clip { xmin: c.asym.0, xmax: c.asym.1 };
        let asym_loss = quant_sq_error(&c.input, asym_clip, 4);
        // The golden file stores losses at 9 significant digits, so allow
        // rounding slack when greedy == asym (no improvement found).
        assert!(
            c.greedy_loss <= asym_loss * (1.0 + 1e-8),
            "case {i}: python greedy {} worse than asym {asym_loss}",
            c.greedy_loss
        );
    }
}
