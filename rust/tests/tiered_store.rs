//! Tiered slice storage, end to end: serving under a resident-bytes
//! budget must be bit-identical to unlimited-budget serving on the same
//! trace, the size report must honor the budget and reconcile resident +
//! spilled bytes against the catalog, and corrupt spill files must
//! degrade cleanly instead of panicking.

use emberq::coordinator::{BatchPolicy, EmbeddingServer, ServerConfig, TableSet};
use emberq::data::trace::{RequestTrace, TraceConfig};
use emberq::quant::GreedyQuantizer;
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};

fn fused_set(num_tables: usize, rows: usize, dim: usize, seed: u64) -> TableSet {
    TableSet::new(
        (0..num_tables)
            .map(|t| {
                let tab = EmbeddingTable::randn_sigma(rows, dim, 0.1, seed + 13 * t as u64);
                AnyTable::Fused(tab.quantize_fused(
                    &GreedyQuantizer::default(),
                    4,
                    ScaleBiasDtype::F16,
                ))
            })
            .collect(),
    )
}

fn trace(num_tables: usize, rows: usize, seed: u64) -> RequestTrace {
    RequestTrace::generate(&TraceConfig {
        requests: 120,
        num_tables,
        rows,
        mean_pool: 6,
        zipf_alpha: 1.2,
        seed,
    })
}

/// The acceptance bar: with `--resident-budget` set below the total
/// table bytes, `serve_trace` output is bit-identical to the
/// unlimited-budget run on the same trace, and the size report shows
/// resident bytes <= budget.
#[test]
fn budgeted_serve_trace_is_bit_identical_and_within_budget() {
    let seed = 0x7E1A;
    let unlimited_set = fused_set(6, 600, 16, seed);
    let budgeted_set = fused_set(6, 600, 16, seed);
    let logical = unlimited_set.size_bytes();
    let budget = logical * 2 / 5; // well below the table bytes
    let batch = BatchPolicy { max_batch: 16, ..Default::default() };
    let unlimited = EmbeddingServer::start(
        unlimited_set,
        ServerConfig {
            num_shards: 3,
            small_table_rows: usize::MAX,
            batch,
            ..Default::default()
        },
    );
    let budgeted = EmbeddingServer::start(
        budgeted_set,
        ServerConfig {
            num_shards: 3,
            small_table_rows: usize::MAX,
            batch,
            resident_budget: Some(budget),
            ..Default::default()
        },
    );
    let tr = trace(6, 600, seed + 1);
    // The replay's output, request by request, through the same batched
    // path serve_trace drives.
    let fw = unlimited.feature_width();
    for chunk in tr.requests.chunks(16) {
        let mut a = vec![0.0f32; chunk.len() * fw];
        let mut b = vec![1.0f32; chunk.len() * fw]; // stale garbage must vanish
        unlimited.lookup_batch_into(chunk, &mut a);
        budgeted.lookup_batch_into(chunk, &mut b);
        assert_eq!(a, b, "tiered serving must not move a bit of output");
    }
    // And the metrics replay itself accounts identically.
    let mu = unlimited.serve_trace(&tr);
    let mb = budgeted.serve_trace(&tr);
    assert_eq!(mu.requests, mb.requests);
    assert_eq!(mu.lookups, mb.lookups);
    let report = budgeted.size_report();
    assert_eq!(report.resident_budget, Some(budget));
    assert!(
        report.engine_bytes <= budget,
        "resident {} B exceeds the {budget} B budget",
        report.engine_bytes
    );
    assert_eq!(report.engine_bytes + report.spilled_bytes, logical, "tiers reconcile");
    assert!(report.spilled_bytes > 0, "a sub-logical budget must spill something");
    let stats = budgeted.store_stats().expect("tiered storage active");
    assert!(stats.promotions > 0, "the spill path must actually execute");
    assert_eq!(stats.spill_errors, 0);
    // Per-shard tier counters flow into the replay metrics snapshot.
    let per_shard: u64 = mb.per_shard.iter().map(|s| s.promotions).sum();
    assert!(per_shard > 0, "serve_trace window must see promotions");
    assert!(budgeted.stats_text().contains("spilled"), "{}", budgeted.stats_text());
}

/// Resident + spilled bytes reconcile with the catalog's logical totals
/// as slices move between tiers (fused slices carve byte-exactly).
#[test]
fn size_report_reconciles_with_catalog_across_transitions() {
    let set = fused_set(4, 512, 8, 0x7E2A);
    let server = EmbeddingServer::start(
        set,
        ServerConfig {
            num_shards: 2,
            small_table_rows: usize::MAX,
            resident_budget: Some(usize::MAX >> 1), // store on, nothing forced out
            ..Default::default()
        },
    );
    let logical = server.catalog().table_bytes();
    let check = |when: &str| {
        let r = server.size_report();
        assert_eq!(
            r.engine_bytes + r.spilled_bytes,
            logical + r.replicated_bytes,
            "{when}: resident {} + spilled {} must reconcile with catalog {} + replicas {}",
            r.engine_bytes,
            r.spilled_bytes,
            logical,
            r.replicated_bytes
        );
        assert_eq!(r.per_shard_bytes.iter().sum::<usize>(), r.engine_bytes, "{when}");
    };
    check("fresh");
    let tr = trace(4, 512, 0x7E2B);
    let _ = server.serve_trace(&tr);
    check("after traffic");
    let _ = server.rebalance_once(); // may replicate the Zipf-hot table
    check("after a rebalance pass");
    server.validate_routing().expect("routing stays valid with tiering on");
}

/// A corrupt or truncated spill file is a clean error: the touched
/// segment is zeroed and counted, no panic escapes, and every resident
/// slice keeps serving bit-exactly.
#[test]
fn corrupt_spill_files_degrade_cleanly() {
    let spill_dir = std::env::temp_dir()
        .join(format!("emberq_tiered_corrupt_{}", std::process::id()));
    let reference = fused_set(3, 200, 8, 0x7E3A);
    let set = fused_set(3, 200, 8, 0x7E3A);
    let per_table = set.size_bytes() / 3;
    let server = EmbeddingServer::start(
        set,
        ServerConfig {
            num_shards: 2,
            small_table_rows: usize::MAX,
            // Budget for exactly two tables: the coldest third spills.
            resident_budget: Some(2 * per_table),
            spill_dir: Some(spill_dir.clone()),
            ..Default::default()
        },
    );
    // Find which table spilled by probing the report.
    assert_eq!(server.size_report().spilled_bytes, per_table);
    // Garble every spill file on disk.
    let mut garbled = 0usize;
    for entry in std::fs::read_dir(&spill_dir).unwrap() {
        let path = entry.unwrap().path();
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        garbled += 1;
    }
    assert!(garbled > 0, "budget must have produced spill files");
    // Touch all three tables. The spilled one's segment comes back
    // zeroed (clean degradation); the resident ones stay bit-exact.
    let req = emberq::data::trace::Request {
        ids: vec![vec![0, 199], vec![5, 5], vec![17]],
    };
    let got = server.lookup(&req);
    let mut zeroed_segments = 0;
    for (t, ids) in req.ids.iter().enumerate() {
        let mut want = vec![0.0f32; 8];
        reference.pool(t, ids, &mut want);
        let seg = &got[t * 8..(t + 1) * 8];
        if seg == want.as_slice() {
            continue;
        }
        assert!(seg.iter().all(|&v| v == 0.0), "table {t}: degraded segment must be zeroed");
        zeroed_segments += 1;
    }
    assert_eq!(zeroed_segments, 1, "exactly the spilled table degrades");
    let stats = server.store_stats().expect("tiered");
    assert!(stats.spill_errors > 0, "the corrupt file must be counted");
    let per_shard = server.shard_stats().expect("sharded");
    assert_eq!(
        per_shard.iter().map(|s| s.spill_errors).sum::<u64>(),
        stats.spill_errors
    );
    assert_eq!(per_shard.iter().map(|s| s.panics).sum::<u64>(), 0, "no panics");
    // The stats text renders the error without wedging anything.
    assert!(server.stats_text().contains("spill errors"));
    drop(server);
    let _ = std::fs::remove_dir_all(&spill_dir);
}
