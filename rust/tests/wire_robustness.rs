//! Wire-path robustness: truncated, oversized, and garbage frames
//! against BOTH TCP fronts.
//!
//! The wire decoder trusts nothing: every declared length is checked
//! against the documented frame limits *before* any allocation, limit
//! violations come back as clean error frames (then a close), and a
//! structurally unframeable stream is closed without desynchronizing.
//! These tests drive raw sockets — no client-library framing to hide
//! behind — and every property is asserted for the reactor front and
//! the legacy blocking front alike, since both must hold the line.
//!
//! The "before any allocation" claim is tested by construction: the
//! oversize tests declare multi-gigabyte payloads and never send them.
//! A decoder that allocated-and-read the declared size would sit
//! waiting for bytes that never come (and trip the socket timeout);
//! the error frame arriving proves the refusal happened on the header
//! alone.

use std::io::{Read, Write};
use std::net::{Shutdown, SocketAddr, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use emberq::coordinator::frame::{ERR_SENTINEL, UPDATE_SENTINEL};
use emberq::coordinator::{
    EmbeddingServer, ReactorFront, ServerConfig, TableSet, TcpClient, TcpFront,
};
use emberq::quant::GreedyQuantizer;
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};

fn make_server() -> Arc<EmbeddingServer> {
    let tables: Vec<AnyTable> = (0..3)
        .map(|t| {
            let tab = EmbeddingTable::randn(40, 8, 9200 + t);
            AnyTable::Fused(tab.quantize_fused(
                &GreedyQuantizer::default(),
                4,
                ScaleBiasDtype::F16,
            ))
        })
        .collect();
    Arc::new(EmbeddingServer::start(
        TableSet::new(tables),
        ServerConfig { num_shards: 2, ..Default::default() },
    ))
}

enum AnyFront {
    Reactor(ReactorFront),
    Blocking(TcpFront),
}

impl AnyFront {
    fn addr(&self) -> SocketAddr {
        match self {
            AnyFront::Reactor(f) => f.addr(),
            AnyFront::Blocking(f) => f.addr(),
        }
    }
}

/// Run `check` against a fresh server behind each front, so every
/// robustness property is proven for the reactor AND the blocking path.
fn on_both_fronts(check: impl Fn(&AnyFront)) {
    for kind in ["reactor", "blocking"] {
        let server = make_server();
        let front = match kind {
            "reactor" => AnyFront::Reactor(
                ReactorFront::start(Arc::clone(&server), "127.0.0.1:0").unwrap(),
            ),
            _ => AnyFront::Blocking(TcpFront::start(Arc::clone(&server), "127.0.0.1:0").unwrap()),
        };
        check(&front);
    }
}

fn raw_conn(addr: SocketAddr) -> TcpStream {
    let s = TcpStream::connect(addr).unwrap();
    s.set_nodelay(true).unwrap();
    // A decoder that waits for a declared-but-unsent payload shows up
    // as a clean failure here rather than a hung test.
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    s
}

fn read_error_frame(s: &mut TcpStream) -> String {
    let mut head = [0u8; 8];
    s.read_exact(&mut head).unwrap();
    assert_eq!(
        u32::from_le_bytes(head[0..4].try_into().unwrap()),
        ERR_SENTINEL,
        "expected an error frame"
    );
    let len = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
    let mut msg = vec![0u8; len];
    s.read_exact(&mut msg).unwrap();
    String::from_utf8_lossy(&msg).into_owned()
}

fn assert_eof(s: &mut TcpStream) {
    let mut b = [0u8; 1];
    let n = s.read(&mut b).unwrap_or(0);
    assert_eq!(n, 0, "peer should have closed the connection");
}

fn assert_still_serving(addr: SocketAddr) {
    let mut c = TcpClient::connect(addr).unwrap();
    let out = c.lookup(&[vec![1], vec![2], vec![3]]).unwrap();
    assert_eq!(out.len(), 24, "server must keep serving after abuse");
}

#[test]
fn truncated_lookup_then_disconnect_leaves_the_server_serving() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        s.write_all(&3u32.to_le_bytes()).unwrap(); // table count...
        s.write_all(&0u32.to_le_bytes()).unwrap(); // ...one table id, then vanish
        s.shutdown(Shutdown::Write).unwrap();
        assert_eof(&mut s); // half a frame is owed nothing
        assert_still_serving(front.addr());
    });
}

#[test]
fn truncated_update_then_disconnect_leaves_the_server_serving() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        s.write_all(&UPDATE_SENTINEL.to_le_bytes()).unwrap();
        s.write_all(&0u32.to_le_bytes()).unwrap(); // valid table, then vanish
        s.shutdown(Shutdown::Write).unwrap();
        assert_eof(&mut s);
        assert_still_serving(front.addr());
    });
}

#[test]
fn oversized_lookup_length_is_refused_before_allocation() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        s.write_all(&1u32.to_le_bytes()).unwrap();
        s.write_all(&0u32.to_le_bytes()).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap(); // ~4G ids declared, none sent
        let msg = read_error_frame(&mut s);
        assert!(msg.contains("per-field cap"), "{msg}");
        assert_eof(&mut s);
        assert_still_serving(front.addr());
    });
}

#[test]
fn oversized_update_row_count_is_refused_before_allocation() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        s.write_all(&UPDATE_SENTINEL.to_le_bytes()).unwrap();
        s.write_all(&0u32.to_le_bytes()).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap(); // ~4G rows declared, none sent
        let msg = read_error_frame(&mut s);
        assert!(msg.contains("per-field cap"), "{msg}");
        assert_eof(&mut s);
        assert_still_serving(front.addr());
    });
}

#[test]
fn absurd_table_count_is_refused_on_the_header_alone() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        // Garbage that still parses as a lookup header: 0xDEADBEEF
        // tables could never fit in a frame, so the budget check fires
        // before any entry is read.
        s.write_all(&0xDEAD_BEEFu32.to_le_bytes()).unwrap();
        let msg = read_error_frame(&mut s);
        assert!(msg.contains("frame limit"), "{msg}");
        assert_eof(&mut s);
        assert_still_serving(front.addr());
    });
}

#[test]
fn update_with_unknown_table_is_a_silent_close() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        s.write_all(&UPDATE_SENTINEL.to_le_bytes()).unwrap();
        s.write_all(&99u32.to_le_bytes()).unwrap(); // no such table: no dim
        s.write_all(&1u32.to_le_bytes()).unwrap();
        // Without a dim the payload cannot be framed, so the front
        // closes rather than desynchronize. No error frame is owed.
        assert_eof(&mut s);
        assert_still_serving(front.addr());
    });
}

#[test]
fn last_request_before_half_close_still_gets_its_reply() {
    on_both_fronts(|front| {
        let mut s = raw_conn(front.addr());
        // A complete, valid 3-table lookup, then write-side shutdown:
        // the request was fully delivered, so a reply is owed even
        // though no more bytes will ever arrive.
        s.write_all(&3u32.to_le_bytes()).unwrap();
        for t in 0..3u32 {
            s.write_all(&t.to_le_bytes()).unwrap();
            s.write_all(&1u32.to_le_bytes()).unwrap();
            s.write_all(&t.to_le_bytes()).unwrap(); // row id = t
        }
        s.shutdown(Shutdown::Write).unwrap();
        let mut head = [0u8; 4];
        s.read_exact(&mut head).unwrap();
        let n = u32::from_le_bytes(head) as usize;
        assert_eq!(n, 24, "3 tables x dim 8");
        let mut payload = vec![0u8; n * 4];
        s.read_exact(&mut payload).unwrap();
        assert_eof(&mut s);
        assert_still_serving(front.addr());
    });
}

#[test]
fn garbage_after_a_valid_frame_poisons_only_that_connection() {
    on_both_fronts(|front| {
        let mut c = TcpClient::connect(front.addr()).unwrap();
        assert_eq!(c.lookup(&[vec![1], vec![2], vec![3]]).unwrap().len(), 24);
        // Now a different connection goes hostile mid-session...
        let mut s = raw_conn(front.addr());
        s.write_all(&1u32.to_le_bytes()).unwrap();
        s.write_all(&0u32.to_le_bytes()).unwrap();
        s.write_all(&2u32.to_le_bytes()).unwrap();
        s.write_all(&5u32.to_le_bytes()).unwrap();
        s.write_all(&7u32.to_le_bytes()).unwrap(); // a valid 1-table lookup
        let mut head = [0u8; 8];
        s.read_exact(&mut head).unwrap();
        // (Arity error frame — the server has 3 tables — but framed.)
        assert_eq!(u32::from_le_bytes(head[0..4].try_into().unwrap()), ERR_SENTINEL);
        let len = u32::from_le_bytes(head[4..8].try_into().unwrap()) as usize;
        let mut msg = vec![0u8; len];
        s.read_exact(&mut msg).unwrap();
        s.write_all(&u32::MAX.to_le_bytes()).unwrap(); // ...then garbage
        s.shutdown(Shutdown::Write).unwrap();
        let mut sink = Vec::new();
        let _ = s.read_to_end(&mut sink); // error frame or close; either is fine
        // ...while the polite connection keeps working.
        assert_eq!(c.lookup(&[vec![4], vec![5], vec![6]]).unwrap().len(), 24);
        assert_still_serving(front.addr());
    });
}
