//! Integration: quantization methods × table formats × SLS kernels
//! working together on realistic (trained-statistics) tables.

use emberq::eval::{normalized_l2_fused, normalized_l2_method};
use emberq::quant::{method_by_name, Method};
use emberq::sls::{sls_f32, sls_fused, SlsArgs};
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

/// A table whose row statistics resemble Adagrad-trained embeddings: hot
/// rows (low ranks) get larger magnitudes, cold rows stay near init.
fn trained_like_table(rows: usize, dim: usize, seed: u64) -> EmbeddingTable {
    let mut rng = Rng::new(seed);
    let mut t = EmbeddingTable::zeros(rows, dim);
    for r in 0..rows {
        let heat = 1.0 / (1.0 + r as f64 / 50.0); // popularity decays with rank
        let sigma = (0.02 + 0.3 * heat) as f32;
        for v in t.row_mut(r) {
            *v = (rng.normal() as f32) * sigma + (rng.uniform_in(-0.01, 0.01) as f32);
        }
    }
    t
}

#[test]
fn every_method_quantizes_trained_table() {
    let t = trained_like_table(300, 64, 1);
    for name in [
        "TABLE", "ASYM", "SYM", "GSS", "HIST-APPRX", "HIST-BRUTE", "ACIQ", "GREEDY",
        "KMEANS", "KMEANS-CLS",
    ] {
        let m = method_by_name(name).unwrap();
        let l2 = normalized_l2_method(&t, &m, 4, ScaleBiasDtype::F32);
        assert!(l2.is_finite() && l2 >= 0.0, "{name}: {l2}");
        assert!(l2 < 0.5, "{name}: unreasonable loss {l2}");
    }
}

#[test]
fn paper_method_ranking_on_trained_stats() {
    // Table 2's qualitative story on trained-like rows: GREEDY <= ASYM,
    // KMEANS best, SYM worst of the row-wise methods.
    let t = trained_like_table(200, 64, 2);
    let loss = |n: &str| {
        normalized_l2_method(&t, &method_by_name(n).unwrap(), 4, ScaleBiasDtype::F32)
    };
    let (greedy, asym, sym, kmeans) = (loss("GREEDY"), loss("ASYM"), loss("SYM"), loss("KMEANS"));
    assert!(greedy <= asym + 1e-9, "greedy {greedy} vs asym {asym}");
    assert!(kmeans < greedy, "kmeans {kmeans} vs greedy {greedy}");
    assert!(sym > asym, "sym {sym} vs asym {asym}");
}

#[test]
fn fp16_tails_cost_nothing_measurable() {
    let t = trained_like_table(200, 32, 3);
    let m = method_by_name("GREEDY").unwrap();
    let l32 = normalized_l2_method(&t, &m, 4, ScaleBiasDtype::F32);
    let l16 = normalized_l2_method(&t, &m, 4, ScaleBiasDtype::F16);
    assert!((l16 - l32).abs() / l32 < 0.01, "{l32} vs {l16}");
}

#[test]
fn quantized_sls_tracks_fp32_sls() {
    // End-to-end: quantize -> pooled lookups -> compare against FP32
    // pooling. Pooling does not shrink *relative* error (signal and noise
    // both grow ~sqrt(L)), so the pooled relative error matches the
    // row-level normalized l2 — Table 2 says ~6% for 4-bit GREEDY; we
    // bound at 12%.
    let t = trained_like_table(500, 64, 4);
    let Method::Uniform(q) = method_by_name("GREEDY").unwrap() else {
        unreachable!()
    };
    let f = t.quantize_fused(q.as_ref(), 4, ScaleBiasDtype::F16);
    let mut rng = Rng::new(5);
    let lengths: Vec<u32> = (0..20).map(|_| 1 + rng.below(30) as u32).collect();
    let total: usize = lengths.iter().map(|&l| l as usize).sum();
    // Zipf-ish: favor hot rows like production traffic.
    let indices: Vec<u32> = (0..total)
        .map(|_| ((rng.uniform().powi(3) * 500.0) as u32).min(499))
        .collect();
    let args = SlsArgs::new(&indices, &lengths, 500).unwrap();
    let mut exact = vec![0.0f32; 20 * 64];
    let mut quant = exact.clone();
    sls_f32(&t, &args, &mut exact);
    sls_fused(&f, &args, &mut quant);
    let num: f64 = exact.iter().zip(&quant).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
    let den: f64 = exact.iter().map(|&a| (a as f64).powi(2)).sum();
    assert!((num / den.max(1e-12)).sqrt() < 0.12, "rel {}", (num / den).sqrt());
}

#[test]
fn eight_bit_baseline_is_order_of_magnitude_tighter() {
    // ASYM-8BITS vs 4-bit methods: Table 2 shows ~15x lower loss.
    let t = trained_like_table(200, 64, 6);
    let Method::Uniform(q) = method_by_name("ASYM").unwrap() else {
        unreachable!()
    };
    let l8 = normalized_l2_fused(&t, &t.quantize_fused(q.as_ref(), 8, ScaleBiasDtype::F32));
    let l4 = normalized_l2_fused(&t, &t.quantize_fused(q.as_ref(), 4, ScaleBiasDtype::F32));
    assert!(l4 / l8 > 8.0, "l4 {l4} l8 {l8}");
}

#[test]
fn greedy_opt_explores_further() {
    // Fig 1's GREEDY (opt): larger b/r never loses on average.
    let mut sum_def = 0.0;
    let mut sum_opt = 0.0;
    for seed in 0..10 {
        let t = trained_like_table(50, 128, 100 + seed);
        sum_def +=
            normalized_l2_method(&t, &method_by_name("GREEDY").unwrap(), 4, ScaleBiasDtype::F32);
        sum_opt += normalized_l2_method(
            &t,
            &method_by_name("GREEDY-OPT").unwrap(),
            4,
            ScaleBiasDtype::F32,
        );
    }
    assert!(sum_opt <= sum_def * 1.001, "opt {sum_opt} vs def {sum_def}");
}
