//! Residency: slice-resident sharded serving must hold ≈1× the table
//! bytes. The PR-1 engine kept the leader's full `TableSet` next to the
//! shard slices (~2× residency); these tests pin the new ownership model
//! through the public `SizeReport` breakdown — engine-resident vs
//! catalog-resident bytes — at the server and engine layers.

use emberq::coordinator::{EmbeddingServer, ServerConfig, TableSet};
use emberq::data::trace::Request;
use emberq::quant::GreedyQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};

fn fused_set(num_tables: usize, rows: usize, dim: usize) -> TableSet {
    TableSet::new(
        (0..num_tables)
            .map(|t| {
                let tab = EmbeddingTable::randn_sigma(rows, dim, 0.1, 0xD0 + t as u64);
                AnyTable::Fused(tab.quantize_fused(
                    &GreedyQuantizer::default(),
                    4,
                    ScaleBiasDtype::F16,
                ))
            })
            .collect(),
    )
}

#[test]
fn sharded_residency_is_one_x_plus_catalog_epsilon() {
    // The acceptance bar: engine-resident bytes == 1× the quantized
    // table bytes (f32/fused carving is byte-exact), catalog overhead
    // < 1%, across shard counts and both placement regimes.
    for shards in [1usize, 2, 4, 8] {
        for small_table_rows in [0usize, usize::MAX] {
            let set = fused_set(4, 3_000, 32);
            let logical = set.size_bytes();
            let engine = ShardedEngine::start(
                set,
                &ShardConfig { num_shards: shards, small_table_rows, ..Default::default() },
            );
            assert_eq!(engine.table_bytes(), logical);
            assert_eq!(
                engine.shard_bytes().iter().sum::<usize>(),
                logical,
                "shards={shards} small_table_rows={small_table_rows}"
            );
            assert_eq!(engine.replicated_bytes(), 0);
        }
    }
    // Server-level report: catalog epsilon and ratio.
    let set = fused_set(4, 3_000, 32);
    let logical = set.size_bytes();
    let server =
        EmbeddingServer::start(set, ServerConfig { num_shards: 4, ..Default::default() });
    let report = server.size_report();
    assert_eq!(report.table_bytes, logical);
    assert_eq!(report.engine_bytes, logical);
    assert!(
        report.catalog_overhead() < 0.01,
        "catalog {} B vs tables {} B",
        report.catalog_bytes,
        report.table_bytes
    );
    assert!(report.residency_ratio() < 1.01, "ratio {}", report.residency_ratio());
    assert_eq!(report.per_shard_bytes.len(), 4);
    assert_eq!(report.per_shard_bytes.iter().sum::<usize>(), report.engine_bytes);
}

#[test]
fn codebook_residency_overhead_is_bounded() {
    // Two-tier codebook slices each keep the (small) shared codebooks,
    // so residency may exceed 1× — but only by the codebook bytes.
    let set = TableSet::new(
        (0..2)
            .map(|t| {
                let tab = EmbeddingTable::randn(2_000, 16, 0xE0 + t as u64);
                AnyTable::Codebook(
                    tab.quantize_codebook(CodebookKind::TwoTier { k: 4 }, ScaleBiasDtype::F16),
                )
            })
            .collect(),
    );
    let logical = set.size_bytes();
    let engine = ShardedEngine::start(
        set,
        &ShardConfig { num_shards: 4, small_table_rows: 0, ..Default::default() },
    );
    let resident: usize = engine.shard_bytes().iter().sum();
    assert!(resident >= logical);
    assert!(
        (resident as f64) < 1.05 * logical as f64,
        "codebook residency {resident} vs logical {logical}"
    );
}

#[test]
fn replication_cost_is_exactly_the_replicas() {
    // Hot replication trades bytes for skew: the report must show the
    // exact cost, and residency stays 1× + replicas.
    let set = fused_set(3, 256, 16); // whole tables under the default threshold
    let logical = set.size_bytes();
    let per_table = logical / 3;
    let server = EmbeddingServer::start(
        set,
        ServerConfig { num_shards: 4, replicate_hot: 1, ..Default::default() },
    );
    let report = server.size_report();
    assert_eq!(report.table_bytes, logical);
    assert_eq!(report.replicated_bytes, 3 * per_table); // 3 extra copies
    assert_eq!(report.engine_bytes, logical + report.replicated_bytes);
    // Serving still works and matches the catalog's shape claims.
    let req = Request { ids: vec![vec![0, 255], vec![17], vec![42]] };
    assert_eq!(server.lookup(&req).len(), server.feature_width());
}

#[test]
fn residency_report_survives_serving_traffic() {
    // The report is static accounting: serving must not change it.
    let set = fused_set(2, 1_000, 8);
    let server =
        EmbeddingServer::start(set, ServerConfig { num_shards: 2, ..Default::default() });
    let before = server.size_report();
    for i in 0..50u32 {
        let req = Request { ids: vec![vec![i, 999 - i], vec![i * 3]] };
        let _ = server.lookup(&req);
    }
    let after = server.size_report();
    assert_eq!(before.engine_bytes, after.engine_bytes);
    assert_eq!(before.catalog_bytes, after.catalog_bytes);
    // ... but the per-shard service stats did move.
    let stats = server.shard_stats().expect("sharded");
    assert_eq!(stats.iter().map(|s| s.lookups).sum::<u64>(), 150);
}
