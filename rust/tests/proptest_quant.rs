//! Property-based tests for the quantizers: randomized inputs over many
//! seeds, asserting the invariants every method must satisfy regardless
//! of the data. (Hand-rolled property loop — the crate builds offline
//! with no test-framework dependencies; 200 cases per property.)

use emberq::quant::{
    all_uniform, quant_dequant, quant_sq_error, AsymQuantizer, GreedyQuantizer,
    KmeansQuantizer, Quantizer,
};
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

const CASES: u64 = 200;

/// Random row generator covering the regimes that break quantizers:
/// scale varies over 6 orders of magnitude, mean offsets, heavy tails,
/// near-constant rows, tiny dims.
fn random_row(rng: &mut Rng) -> Vec<f32> {
    let d = [1, 2, 3, 8, 16, 33, 64, 128, 200][rng.below(9)];
    let sigma = 10f64.powf(rng.uniform_in(-3.0, 3.0));
    let mu = rng.uniform_in(-10.0, 10.0);
    let heavy = rng.uniform() < 0.3;
    let near_const = rng.uniform() < 0.1;
    (0..d)
        .map(|_| {
            if near_const {
                mu as f32 + (rng.uniform() as f32) * 1e-6
            } else if heavy {
                (mu + sigma * rng.laplace().powi(3)) as f32
            } else {
                (mu + sigma * rng.normal()) as f32
            }
        })
        .collect()
}

#[test]
fn prop_clip_finite_and_ordered() {
    let mut rng = Rng::new(0xA0);
    for case in 0..CASES {
        let row = random_row(&mut rng);
        for q in all_uniform() {
            let c = q.clip(&row, 4);
            assert!(c.xmin.is_finite() && c.xmax.is_finite(), "{} case {case}", q.name());
            assert!(c.xmin <= c.xmax, "{} case {case}: {c:?}", q.name());
        }
    }
}

#[test]
fn prop_dequant_within_clip_bounds() {
    // Reconstructed values never escape [xmin, xmax] (+ float slack).
    let mut rng = Rng::new(0xA1);
    for _ in 0..CASES {
        let row = random_row(&mut rng);
        for q in all_uniform() {
            let c = q.clip(&row, 4);
            let slack = (c.xmax - c.xmin).abs() * 1e-5 + 1e-6;
            for v in quant_dequant(&row, c, 4) {
                assert!(
                    v >= c.xmin - slack && v <= c.xmax + slack,
                    "{}: {v} outside [{}, {}]",
                    q.name(),
                    c.xmin,
                    c.xmax
                );
            }
        }
    }
}

#[test]
fn prop_greedy_never_worse_than_asym() {
    // The paper's construction guarantee, on arbitrary data.
    let mut rng = Rng::new(0xA2);
    for case in 0..CASES {
        let row = random_row(&mut rng);
        let eg = quant_sq_error(&row, GreedyQuantizer::default().clip(&row, 4), 4);
        let ea = quant_sq_error(&row, AsymQuantizer.clip(&row, 4), 4);
        assert!(eg <= ea + 1e-9, "case {case}: greedy {eg} > asym {ea}");
    }
}

#[test]
fn prop_more_bits_never_hurt() {
    let mut rng = Rng::new(0xA3);
    for _ in 0..CASES {
        let row = random_row(&mut rng);
        let c = AsymQuantizer.clip(&row, 4);
        let e4 = quant_sq_error(&row, c, 4);
        let e8 = quant_sq_error(&row, c, 8);
        assert!(e8 <= e4 + 1e-9, "8-bit {e8} worse than 4-bit {e4}");
    }
}

#[test]
fn prop_kmeans_beats_every_uniform_method() {
    // A 16-entry free codebook is a superset of any 16-point uniform grid,
    // so KMEANS-with-grid-init can never lose to ASYM (its init).
    let mut rng = Rng::new(0xA4);
    for case in 0..CASES {
        let row = random_row(&mut rng);
        let (cb, codes) = KmeansQuantizer::default().quantize_row(&row);
        let ek: f64 = row
            .iter()
            .zip(&codes)
            .map(|(&x, &c)| ((x - cb[c as usize]) as f64).powi(2))
            .sum();
        let ea = quant_sq_error(&row, AsymQuantizer.clip(&row, 4), 4);
        assert!(ek <= ea + 1e-9, "case {case}: kmeans {ek} > asym {ea}");
    }
}

#[test]
fn prop_fused_round_trip_error_bounded() {
    // Pack -> unpack through FusedTable obeys the half-scale bound for
    // in-range values under every uniform method.
    let mut rng = Rng::new(0xA5);
    for case in 0..50 {
        let d = [8usize, 15, 64][rng.below(3)];
        let t = EmbeddingTable::randn_sigma(8, d, 10f32.powi(rng.below(5) as i32 - 2), case);
        for q in all_uniform() {
            let f = t.quantize_fused(q.as_ref(), 4, ScaleBiasDtype::F32);
            for r in 0..t.rows() {
                let (scale, bias) = f.read_tail(f.row_raw(r));
                let hi = bias + scale * 15.0;
                let dq = f.dequantize_row(r);
                for (j, (&orig, &rec)) in t.row(r).iter().zip(&dq).enumerate() {
                    // In-range values: within half a step. Clipped values:
                    // reconstruct to the nearest end.
                    let clamped = orig.clamp(bias, hi);
                    assert!(
                        (clamped - rec).abs() <= scale / 2.0 + scale.abs() * 1e-3 + 1e-5,
                        "{} case {case} row {r} col {j}: {orig} -> {rec} (scale {scale})",
                        q.name()
                    );
                }
            }
        }
    }
}

#[test]
fn prop_quantize_deterministic() {
    let mut rng = Rng::new(0xA6);
    for _ in 0..50 {
        let row = random_row(&mut rng);
        for q in all_uniform() {
            let a = q.clip(&row, 4);
            let b = q.clip(&row, 4);
            assert_eq!(a, b, "{} not deterministic", q.name());
        }
    }
}
