//! End-to-end CLI tests for `emberq serve` flag handling.
//!
//! These spawn the real binary (`CARGO_BIN_EXE_emberq`), so they cover
//! the full surface a user hits: parsing, validation order, error
//! wording on stderr, exit codes, and the `--help` text. The in-module
//! tests in `cli.rs` call `run()` directly; this suite is the contract
//! for scripts and operators wrapping the executable.

use std::fs::File;
use std::io::BufWriter;
use std::path::PathBuf;
use std::process::{Command, Output};

use emberq::cli::SERVE_FLAGS;
use emberq::table::serial;
use emberq::table::EmbeddingTable;

/// Write a small FP32 table file and return its path.
fn table_file(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("emberq-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join(name);
    let t = EmbeddingTable::randn(64, 8, 31);
    let f = File::create(&path).unwrap();
    serial::write_f32(&mut BufWriter::new(f), &t).unwrap();
    path
}

fn emberq(args: &[&str]) -> Output {
    Command::new(env!("CARGO_BIN_EXE_emberq"))
        .args(args)
        .output()
        .expect("spawn emberq binary")
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

#[test]
fn serve_requires_a_table() {
    let out = emberq(&["serve"]);
    assert!(!out.status.success(), "missing --table must fail");
    assert!(stderr_of(&out).contains("--table required"), "{}", stderr_of(&out));
}

#[test]
fn serve_rejects_bad_update_flag_combos() {
    let p = table_file("combos.embq");
    let p = p.to_str().unwrap();

    // --update-port only makes sense with a TCP front.
    let out = emberq(&["serve", "--table", p, "--update-port", "19999"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--listen"), "{}", stderr_of(&out));

    // Live updates need the row-sharded engine.
    let out = emberq(&["serve", "--table", p, "--shards", "0", "--update-every", "5"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--shards"), "{}", stderr_of(&out));

    // Churn is a trace-mode feature; TCP clients send update frames.
    let out = emberq(&[
        "serve", "--table", p, "--listen", "127.0.0.1:0", "--update-every", "5",
    ]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--update-port"), "{}", stderr_of(&out));

    // A zero-row update batch is meaningless.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--update-every", "1", "--update-rows", "0",
    ]);
    assert!(!out.status.success());
    assert!(
        stderr_of(&out).contains("--update-rows") && stderr_of(&out).contains("at least 1"),
        "{}",
        stderr_of(&out)
    );

    // Validation fires before any server start: a bad numeric flag is a
    // clean one-line error, not a panic.
    let out = emberq(&["serve", "--table", p, "--shards", "not-a-number"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).starts_with("error:"), "{}", stderr_of(&out));
}

#[test]
fn serve_tier_flags_warn_or_fail_cleanly() {
    let p = table_file("tiers.embq");
    let p = p.to_str().unwrap();

    // Tier flags on the table-parallel path: loud warning, run continues.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "0", "--workers", "1", "--copies", "2",
        "--requests", "5", "--batch", "2", "--resident-budget", "4096",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("warning:"), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--resident-budget"), "{}", stderr_of(&out));

    // An uncreatable spill dir fails up front with the flag named.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "5",
        "--spill-dir", "/dev/null/nope",
    ]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--spill-dir"), "{}", stderr_of(&out));

    // --prefetch-window without tiered storage is inert, not fatal.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "5",
        "--batch", "2", "--prefetch-window", "3",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--prefetch-window"), "{}", stderr_of(&out));
}

#[test]
fn serve_update_churn_runs_end_to_end() {
    let p = table_file("churn.embq");
    let out = emberq(&[
        "serve", "--table", p.to_str().unwrap(), "--shards", "2", "--copies", "2",
        "--requests", "200", "--batch", "8", "--update-every", "1", "--update-rows", "4",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(stdout.contains("update churn:"), "{stdout}");
    assert!(stdout.contains("final version"), "{stdout}");
}

#[test]
fn serve_mixed_precision_runs_end_to_end() {
    let p = table_file("mixed.embq");
    let p = p.to_str().unwrap();

    // Warm half the trace, one solver pass at the budget, serve the
    // rest on the swapped formats; the summary line reports the budget
    // point's accuracy cost next to the uniform-int4 baseline.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "200",
        "--batch", "8", "--precision-budget", "1500", "--mixed-precision",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(stdout.contains("mixed precision:"), "{stdout}");
    assert!(stdout.contains("uniform int4"), "{stdout}");
    assert!(stdout.contains("warm half:"), "{stdout}");

    // --mixed-precision without a budget names the missing flag.
    let out = emberq(&["serve", "--table", p, "--shards", "2", "--mixed-precision"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--precision-budget"), "{}", stderr_of(&out));

    // A budget without ticks or a one-shot pass is inert, not fatal.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "5",
        "--batch", "2", "--precision-budget", "100000",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--rebalance-interval"), "{}", stderr_of(&out));

    // On the table-parallel path the budget warns loudly and is ignored.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "0", "--workers", "1", "--copies", "2",
        "--requests", "5", "--batch", "2", "--precision-budget", "100000",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--precision-budget"), "{}", stderr_of(&out));
}

#[test]
fn help_lists_every_serve_flag() {
    // Drift guard against the parser's own source of truth: `cmd_serve`
    // rejects flags outside `emberq::cli::SERVE_FLAGS`, so asserting the
    // help documents every entry covers the parser too — no hand-copied
    // flag list to go stale (the old copy here silently drifted).
    assert!(!SERVE_FLAGS.is_empty());
    let out = emberq(&["serve", "--help"]);
    assert!(out.status.success());
    let help = String::from_utf8_lossy(&out.stdout).into_owned();
    for flag in SERVE_FLAGS {
        assert!(help.contains(flag), "help text is missing `{flag}`");
    }
    // And the same help is reachable the other two documented ways.
    for invocation in [&["--help"][..], &["help"][..]] {
        let out = emberq(invocation);
        assert!(out.status.success());
        assert!(String::from_utf8_lossy(&out.stdout).contains("USAGE: emberq"));
    }
}

#[test]
fn serve_front_and_admission_surface() {
    let p = table_file("front.embq");
    let p = p.to_str().unwrap();

    // An unknown front is a clean one-line error naming the flag.
    let out = emberq(&["serve", "--table", p, "--front", "warp9"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--front"), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("warp9"), "{}", stderr_of(&out));

    // Admission flags without --listen: loud note, run continues (the
    // closed-loop trace replay never sheds).
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "5",
        "--batch", "2", "--slo-ms", "5", "--max-inflight", "8",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--slo-ms"), "{}", stderr_of(&out));

    // Same note for --front without --listen.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "5",
        "--batch", "2", "--front", "blocking",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--front"), "{}", stderr_of(&out));
}

#[test]
fn serve_kernel_backend_surface() {
    let p = table_file("kernel.embq");
    let p = p.to_str().unwrap();

    // A pinned scalar run works everywhere and reports its backend both
    // at startup and in the per-shard stats lines.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "2", "--copies", "2", "--requests", "20",
        "--batch", "8", "--kernel-backend", "scalar",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    assert!(stdout.contains("(scalar kernels)"), "{stdout}");
    assert!(stdout.contains("kernel=scalar"), "{stdout}");

    // An unknown backend is a clean one-line error naming the flag.
    let out = emberq(&["serve", "--table", p, "--kernel-backend", "warp9"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("--kernel-backend"), "{}", stderr_of(&out));

    // Unknown serve flags are rejected against SERVE_FLAGS.
    let out = emberq(&["serve", "--table", p, "--shardz", "2"]);
    assert!(!out.status.success());
    assert!(stderr_of(&out).contains("unknown flag --shardz"), "{}", stderr_of(&out));

    // Pinning on the table-parallel path warns loudly but still runs.
    let out = emberq(&[
        "serve", "--table", p, "--shards", "0", "--workers", "1", "--copies", "2",
        "--requests", "5", "--batch", "2", "--kernel-backend", "scalar",
    ]);
    assert!(out.status.success(), "{}", stderr_of(&out));
    assert!(stderr_of(&out).contains("--kernel-backend"), "{}", stderr_of(&out));
}
