//! Robustness fuzzing of the table container format: random byte
//! corruption, truncation, and random garbage must produce clean
//! `Err`s — never panics, never absurd allocations — because `emberq
//! serve` loads these files from operator-supplied paths.

use emberq::quant::GreedyQuantizer;
use emberq::table::serial::{read_any, write_codebook, write_f32, write_fused, LAYOUT_REVISION};
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

fn valid_files() -> Vec<Vec<u8>> {
    let t = EmbeddingTable::randn(8, 12, 1234);
    let mut out = Vec::new();
    let mut buf = Vec::new();
    write_f32(&mut buf, &t).unwrap();
    out.push(buf);
    let mut buf = Vec::new();
    write_fused(&mut buf, &t.quantize_fused(&GreedyQuantizer::default(), 4, ScaleBiasDtype::F16))
        .unwrap();
    out.push(buf);
    let mut buf = Vec::new();
    write_codebook(&mut buf, &t.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32))
        .unwrap();
    out.push(buf);
    let mut buf = Vec::new();
    write_codebook(
        &mut buf,
        &t.quantize_codebook(CodebookKind::TwoTier { k: 3 }, ScaleBiasDtype::F16),
    )
    .unwrap();
    out.push(buf);
    out
}

#[test]
fn all_valid_files_load() {
    for (i, f) in valid_files().iter().enumerate() {
        assert!(read_any(&mut f.as_slice()).is_ok(), "file {i}");
    }
}

#[test]
fn fuzz_single_byte_corruption() {
    // Flip every byte of the header region (and a sample of the payload)
    // to random values: must load-or-error, never panic. Shape fields are
    // validated before allocation, so corrupted sizes cannot OOM.
    let mut rng = Rng::new(0xF422);
    for (fi, file) in valid_files().iter().enumerate() {
        let header = file.len().min(40);
        for pos in 0..header {
            for _ in 0..4 {
                let mut bad = file.clone();
                bad[pos] = rng.next_u64() as u8;
                let _ = read_any(&mut bad.as_slice()); // Ok or Err, both fine
            }
        }
        for _ in 0..200 {
            let mut bad = file.clone();
            let pos = rng.below(bad.len());
            bad[pos] ^= 1 << rng.below(8);
            let _ = read_any(&mut bad.as_slice());
        }
        let _ = fi;
    }
}

#[test]
fn fuzz_truncation() {
    for file in valid_files() {
        for cut in 0..file.len().min(64) {
            let mut short = file.clone();
            short.truncate(cut);
            assert!(read_any(&mut short.as_slice()).is_err(), "cut={cut}");
        }
        // Also mid-payload truncations.
        for frac in [2usize, 3, 7] {
            let mut short = file.clone();
            short.truncate(file.len() - file.len() / frac);
            assert!(read_any(&mut short.as_slice()).is_err());
        }
    }
}

#[test]
fn fuzz_random_garbage() {
    let mut rng = Rng::new(0xF423);
    for _ in 0..500 {
        let len = rng.below(256);
        let garbage: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
        assert!(read_any(&mut garbage.as_slice()).is_err());
    }
}

#[test]
fn huge_declared_shape_rejected_without_allocation() {
    // Magic + kind 0 + revision + rows=u64::MAX/8, dim=16: rows*dim
    // overflows -> must error out before allocating.
    let mut buf = Vec::new();
    buf.extend_from_slice(b"EMBQTBL2");
    buf.push(0);
    buf.push(LAYOUT_REVISION);
    buf.extend_from_slice(&(u64::MAX / 8).to_le_bytes());
    buf.extend_from_slice(&16u64.to_le_bytes());
    assert!(read_any(&mut buf.as_slice()).is_err());
}
