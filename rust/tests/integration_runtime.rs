//! Integration: the PJRT runtime executing AOT artifacts lowered from
//! JAX/Pallas — the L1/L2/L3 composition proof.
//!
//! Requires `make artifacts`. The MLP artifact is checked *numerically*
//! against the Rust-native MLP on identical weights: the same weights must
//! produce the same logits whether the math runs in Rust or in the
//! XLA-compiled graph.

use std::path::Path;

use emberq::model::{Dlrm, DlrmConfig};
use emberq::runtime::PjrtRuntime;
use emberq::util::Rng;

const MANIFEST_DIR: &str = env!("CARGO_MANIFEST_DIR");

fn artifact(name: &str) -> std::path::PathBuf {
    Path::new(MANIFEST_DIR).join("artifacts").join(name)
}

fn require_artifacts() -> bool {
    let ok = artifact("mlp_b1.hlo.txt").exists();
    if !ok {
        eprintln!("skipping: artifacts missing — run `make artifacts`");
    }
    ok
}

/// Build (inputs, model) for the MLP artifact at the given batch.
fn mlp_inputs(batch: usize) -> (Vec<f32>, Dlrm) {
    // Shapes fixed by python/compile/aot.py.
    let (num_tables, dim, dense_dim) = (8usize, 32usize, 13usize);
    let feature_dim = num_tables * dim + dense_dim;
    let model = Dlrm::new(DlrmConfig {
        num_tables,
        rows_per_table: 4,
        dim,
        dense_dim,
        hidden: vec![512, 512],
        seed: 123,
    });
    let mut rng = Rng::new(9);
    let features: Vec<f32> = (0..batch * feature_dim)
        .map(|_| (rng.normal() as f32) * 0.3)
        .collect();
    (features, model)
}

fn run_mlp(rt: &mut PjrtRuntime, batch: usize, features: &[f32], model: &Dlrm) -> Vec<f32> {
    let feature_dim = model.cfg.feature_dim();
    let mut inputs: Vec<(&[f32], Vec<usize>)> =
        vec![(features, vec![batch, feature_dim])];
    for layer in &model.mlp.layers {
        inputs.push((layer.w.as_slice(), vec![layer.d_out, layer.d_in]));
        inputs.push((layer.b.as_slice(), vec![layer.d_out]));
    }
    let borrowed: Vec<(&[f32], &[usize])> =
        inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
    let name = format!("mlp_b{batch}.hlo.txt");
    let out = rt.execute_f32(&artifact(&name), &borrowed).expect("execute MLP");
    assert_eq!(out.len(), 1, "single tuple element");
    out.into_iter().next().unwrap()
}

#[test]
fn pjrt_mlp_matches_rust_native_mlp() {
    if !require_artifacts() {
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("cpu client");
    for batch in [1usize, 16, 64] {
        let (features, model) = mlp_inputs(batch);
        let pjrt_logits = run_mlp(&mut rt, batch, &features, &model);
        assert_eq!(pjrt_logits.len(), batch);
        let rust_logits = model.mlp.forward(&features, batch);
        for (i, (a, b)) in pjrt_logits.iter().zip(&rust_logits).enumerate() {
            assert!(
                (a - b).abs() < 1e-3 + 1e-3 * b.abs(),
                "batch {batch} logit {i}: pjrt {a} vs rust {b}"
            );
        }
    }
}

#[test]
fn executable_cache_reuses_compilations() {
    if !require_artifacts() {
        return;
    }
    let mut rt = PjrtRuntime::cpu().expect("cpu client");
    let (features, model) = mlp_inputs(1);
    run_mlp(&mut rt, 1, &features, &model);
    assert_eq!(rt.cached(), 1);
    run_mlp(&mut rt, 1, &features, &model);
    assert_eq!(rt.cached(), 1, "second run must not recompile");
}

#[test]
fn missing_artifact_is_a_clean_error() {
    let mut rt = match PjrtRuntime::cpu() {
        Ok(rt) => rt,
        Err(_) => return,
    };
    let err = rt.load(Path::new("artifacts/definitely_not_there.hlo.txt"));
    assert!(err.is_err());
    let msg = format!("{}", err.unwrap_err());
    assert!(msg.contains("make artifacts"), "{msg}");
}

#[test]
fn dlrm_int4_artifact_executes_with_pallas_sls_inside() {
    // The fused Pallas-SLS + MLP graph: feed a tiny quantized table and
    // check the PJRT result against Rust-side dequant + pooling + MLP.
    if !require_artifacts() {
        return;
    }
    let path = artifact("dlrm_int4.hlo.txt");
    // Shapes fixed by aot.py: 4 tables × 256 rows, d=32, B=16, L=8.
    let (t, n, d, b, l, dense_dim) = (4usize, 256usize, 32usize, 16usize, 8usize, 13usize);
    let mut rng = Rng::new(10);
    let packed_u8: Vec<u8> = (0..t * n * d / 2).map(|_| rng.next_u64() as u8).collect();
    let scale: Vec<f32> = (0..t * n).map(|_| 0.01 + rng.uniform() as f32 * 0.05).collect();
    let bias: Vec<f32> = (0..t * n).map(|_| -(rng.uniform() as f32) * 0.5).collect();
    let indices_i32: Vec<i32> = (0..b * t * l)
        .map(|i| {
            let table = (i / l) % t;
            (table * n) as i32 + rng.below(n) as i32
        })
        .collect();
    let weights: Vec<f32> = (0..b * t * l)
        .map(|_| if rng.uniform() < 0.7 { 1.0 } else { 0.0 })
        .collect();
    let dense: Vec<f32> = (0..b * dense_dim).map(|_| rng.normal() as f32).collect();
    let feature_dim = t * d + dense_dim;
    let model = Dlrm::new(DlrmConfig {
        num_tables: t,
        rows_per_table: 4,
        dim: d,
        dense_dim,
        hidden: vec![512, 512],
        seed: 124,
    });

    use emberq::runtime::InputBuf;
    let mut rt = PjrtRuntime::cpu().expect("cpu client");
    let table_shape = [t * n, d / 2];
    let row_shape = [t * n];
    let idx_shape = [b, t, l];
    let dense_shape = [b, dense_dim];
    let mut inputs: Vec<(InputBuf, &[usize])> = vec![
        (InputBuf::U8(&packed_u8), &table_shape),
        (InputBuf::F32(&scale), &row_shape),
        (InputBuf::F32(&bias), &row_shape),
        (InputBuf::I32(&indices_i32), &idx_shape),
        (InputBuf::F32(&weights), &idx_shape),
        (InputBuf::F32(&dense), &dense_shape),
    ];
    let layer_shapes: Vec<([usize; 2], [usize; 1])> = model
        .mlp
        .layers
        .iter()
        .map(|layer| ([layer.d_out, layer.d_in], [layer.d_out]))
        .collect();
    for (layer, (ws, bs)) in model.mlp.layers.iter().zip(&layer_shapes) {
        inputs.push((InputBuf::F32(&layer.w), ws));
        inputs.push((InputBuf::F32(&layer.b), bs));
    }
    let out = rt.execute_mixed(&path, &inputs).expect("execute dlrm_int4");
    let logits = out.into_iter().next().unwrap();
    assert_eq!(logits.len(), b);

    // Rust reference: dequantize, pool with weights, concat dense, MLP.
    let mut features = vec![0.0f32; b * feature_dim];
    for bi in 0..b {
        for ti in 0..t {
            for li in 0..l {
                let flat = (bi * t + ti) * l + li;
                let w = weights[flat];
                if w == 0.0 {
                    continue;
                }
                let row = indices_i32[flat] as usize;
                let s = scale[row];
                let bs = bias[row];
                for j in 0..d {
                    let byte = packed_u8[row * d / 2 + j / 2];
                    let code = if j % 2 == 0 { byte & 0xF } else { byte >> 4 };
                    features[bi * feature_dim + ti * d + j] += w * (s * code as f32 + bs);
                }
            }
        }
        features[bi * feature_dim + t * d..bi * feature_dim + feature_dim]
            .copy_from_slice(&dense[bi * dense_dim..(bi + 1) * dense_dim]);
    }
    let want = model.mlp.forward(&features, b);
    for (i, (a, w)) in logits.iter().zip(&want).enumerate() {
        assert!(
            (a - w).abs() < 1e-2 + 1e-2 * w.abs(),
            "logit {i}: pjrt {a} vs rust {w}"
        );
    }
}
