//! Property tests for the row-wise shard engine: sharded lookups must
//! reproduce the unsharded `TableSet::pool` result for every table
//! format, shard counts 1–8, and adversarial request shapes (hand-rolled
//! property loops — the crate builds offline with no test-framework
//! dependencies).
//!
//! Exactness contract (see the `shard` module docs): sharded output
//! equals the unsharded pool **bit for bit, always** — including when a
//! segment's ids span shards (the engine executes every segment whole,
//! in id order, over the owning chunk slices; it never merges per-shard
//! partial sums, which f32 non-associativity would make inexact), with
//! work stealing on or off, and across replica placements.

use emberq::coordinator::{EmbeddingServer, ServerConfig, TableSet};
use emberq::data::trace::Request;
use emberq::quant::AsymQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

const CASES: usize = 240;

/// Deterministic table builder so the reference set and the engine's set
/// hold identical contents.
fn build_tables(
    seed: u64,
    fmt: usize,
    num_tables: usize,
    rows: usize,
    dim: usize,
) -> Vec<AnyTable> {
    (0..num_tables)
        .map(|t| {
            let tab = EmbeddingTable::randn(rows, dim, seed + 31 * t as u64);
            match fmt {
                0 => AnyTable::F32(tab),
                1 => AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16)),
                2 => AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F32)),
                3 => AnyTable::Codebook(
                    tab.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32),
                ),
                _ => {
                    let k = (1 + t % 3).min(rows);
                    AnyTable::Codebook(
                        tab.quantize_codebook(CodebookKind::TwoTier { k }, ScaleBiasDtype::F16),
                    )
                }
            }
        })
        .collect()
}

/// Request generator biased toward the shapes that break sharding:
/// empty segments, repeated ids, all ids inside one chunk, and ids
/// straddling chunk boundaries.
fn adversarial_ids(rng: &mut Rng, rows: usize, shards: usize) -> Vec<u32> {
    let chunk = rows.div_ceil(shards).max(1);
    match rng.below(5) {
        0 => Vec::new(),
        1 => vec![rng.below(rows) as u32; 1 + rng.below(8)], // one id, repeated
        2 => {
            // All ids inside shard 0's chunk.
            let len = 1 + rng.below(8);
            (0..len).map(|_| rng.below(chunk.min(rows)) as u32).collect()
        }
        3 => {
            let len = rng.below(13); // may be empty
            (0..len).map(|_| rng.below(rows) as u32).collect()
        }
        _ => {
            // Chunk-boundary straddlers.
            let mut ids = vec![0u32, (rows - 1) as u32];
            if chunk < rows {
                ids.push(chunk as u32);
                ids.push((chunk - 1) as u32);
            }
            for _ in 0..rng.below(4) {
                ids.push(rng.below(rows) as u32);
            }
            rng.shuffle(&mut ids);
            ids
        }
    }
}

#[test]
fn prop_sharded_equals_unsharded_pool() {
    let mut rng = Rng::new(0x5A4D);
    for case in 0..CASES {
        let num_tables = 1 + rng.below(4);
        let rows = 1 + rng.below(120);
        let dim = [3usize, 4, 8, 16, 33][rng.below(5)];
        let shards = 1 + (case % 8); // cover every count in 1..=8
        let fmt = case % 5;
        // Quarter of the cases force whole-table placement; the rest
        // split row-wise.
        let small_table_rows = if rng.below(4) == 0 { usize::MAX } else { 0 };
        let seed = 0xE0_0000 + case as u64 * 101;
        let reference = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
        let engine_set = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
        let engine = ShardedEngine::start(
            engine_set, // consumed: the engine's slices own the rows
            &ShardConfig {
                num_shards: shards,
                queue_depth: 1 + rng.below(8),
                small_table_rows,
                ..Default::default()
            },
        );
        let reqs: Vec<Request> = (0..1 + rng.below(5))
            .map(|_| Request {
                ids: (0..num_tables)
                    .map(|_| adversarial_ids(&mut rng, rows, shards))
                    .collect(),
            })
            .collect();
        let fw = engine.feature_width();
        let mut out = vec![0.0f32; reqs.len() * fw];
        engine.lookup_batch_into(&reqs, &mut out);
        for (slot, req) in reqs.iter().enumerate() {
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; dim];
                reference.pool(t, ids, &mut want);
                let got = &out[slot * fw + t * dim..slot * fw + (t + 1) * dim];
                assert_eq!(
                    got,
                    want.as_slice(),
                    "case {case} slot {slot} table {t}: every segment must be bit-exact, \
                     spanning or not (fmt {fmt}, {rows} rows, {shards} shards)"
                );
            }
        }
    }
}

#[test]
fn prop_stealing_is_bit_invariant() {
    // Work stealing changes *who* executes a sub-request, never its
    // arithmetic: engines with stealing on and off (same tables, same
    // requests, shard counts 1..=8, all formats) must agree bitwise with
    // each other and with the unsharded pool, even under spanning ids.
    let mut rng = Rng::new(0x57EA);
    for case in 0..48u64 {
        let num_tables = 1 + rng.below(3);
        let rows = 4 + rng.below(100);
        let dim = [3usize, 4, 8, 16][rng.below(4)];
        let shards = 1 + (case as usize % 8);
        let fmt = case as usize % 5;
        let small_table_rows = if rng.below(3) == 0 { usize::MAX } else { 0 };
        let seed = 0xA5_0000 + case * 131;
        let reference = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
        let mk_engine = |steal: bool| {
            ShardedEngine::start(
                TableSet::new(build_tables(seed, fmt, num_tables, rows, dim)),
                &ShardConfig {
                    num_shards: shards,
                    small_table_rows,
                    steal,
                    ..Default::default()
                },
            )
        };
        let plain = mk_engine(false);
        let stealing = mk_engine(true);
        let reqs: Vec<Request> = (0..2 + rng.below(5))
            .map(|_| Request {
                ids: (0..num_tables)
                    .map(|_| adversarial_ids(&mut rng, rows, shards))
                    .collect(),
            })
            .collect();
        let fw = plain.feature_width();
        let mut a = vec![0.0f32; reqs.len() * fw];
        let mut b = vec![1.0f32; reqs.len() * fw]; // stale garbage must vanish
        plain.lookup_batch_into(&reqs, &mut a);
        stealing.lookup_batch_into(&reqs, &mut b);
        assert_eq!(a, b, "case {case}: stealing must not change a single bit");
        for (slot, req) in reqs.iter().enumerate() {
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; dim];
                reference.pool(t, ids, &mut want);
                assert_eq!(
                    &a[slot * fw + t * dim..slot * fw + (t + 1) * dim],
                    want.as_slice(),
                    "case {case} slot {slot} table {t}"
                );
            }
        }
    }
}

#[test]
fn prop_rebalancing_is_bit_invariant() {
    // Replicas the runtime rebalancer adds (and retires) are
    // byte-identical, so results must not move by a bit across passes.
    let mut rng = Rng::new(0x57EB);
    for case in 0..16u64 {
        let num_tables = 2 + rng.below(3);
        let rows = 8 + rng.below(40);
        let dim = [4usize, 8][rng.below(2)];
        let shards = 2 + rng.below(3);
        let fmt = case as usize % 5;
        let seed = 0xA6_0000 + case * 17;
        let reference = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
        let engine = ShardedEngine::start(
            TableSet::new(build_tables(seed, fmt, num_tables, rows, dim)),
            &ShardConfig {
                num_shards: shards,
                small_table_rows: usize::MAX, // whole tables: replication candidates
                steal: case % 2 == 0,
                ..Default::default()
            },
        );
        let reqs: Vec<Request> = (0..4)
            .map(|_| Request {
                ids: (0..num_tables)
                    .map(|_| adversarial_ids(&mut rng, rows, shards))
                    .collect(),
            })
            .collect();
        let fw = engine.feature_width();
        let mut before = vec![0.0f32; reqs.len() * fw];
        engine.lookup_batch_into(&reqs, &mut before);
        let changed = engine.rebalance_once();
        let mut after = vec![1.0f32; reqs.len() * fw];
        engine.lookup_batch_into(&reqs, &mut after);
        assert_eq!(before, after, "case {case} (placement changed: {changed})");
        for (slot, req) in reqs.iter().enumerate() {
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; dim];
                reference.pool(t, ids, &mut want);
                assert_eq!(
                    &after[slot * fw + t * dim..slot * fw + (t + 1) * dim],
                    want.as_slice(),
                    "case {case} slot {slot} table {t}"
                );
            }
        }
    }
}

#[test]
fn prop_sharded_server_batch_single_and_repeat_consistent() {
    // The ServerConfig { num_shards } integration: batched lookups,
    // single lookups, and repeated runs must all agree bitwise (the
    // engine's shard-ordered merge makes it deterministic).
    let mut rng = Rng::new(0x5A4E);
    for case in 0..40u64 {
        let num_tables = 1 + rng.below(3);
        let rows = 10 + rng.below(100);
        let dim = [4usize, 8, 16][rng.below(3)];
        let shards = 1 + rng.below(8);
        let server = EmbeddingServer::start(
            TableSet::new(build_tables(
                0xF0_0000 + case * 7,
                case as usize % 5,
                num_tables,
                rows,
                dim,
            )),
            ServerConfig { num_shards: shards, ..Default::default() },
        );
        assert!(server.is_sharded());
        let reqs: Vec<Request> = (0..2 + rng.below(6))
            .map(|_| Request {
                ids: (0..num_tables)
                    .map(|_| adversarial_ids(&mut rng, rows, shards))
                    .collect(),
            })
            .collect();
        let fw = num_tables * dim;
        let mut a = vec![0.0f32; reqs.len() * fw];
        let mut b = vec![1.0f32; reqs.len() * fw]; // stale garbage must vanish
        server.lookup_batch_into(&reqs, &mut a);
        server.lookup_batch_into(&reqs, &mut b);
        assert_eq!(a, b, "case {case}: repeated batch runs must agree bitwise");
        for (slot, req) in reqs.iter().enumerate() {
            let single = server.lookup(req);
            assert_eq!(
                &a[slot * fw..(slot + 1) * fw],
                single.as_slice(),
                "case {case} slot {slot}: batch vs single lookup"
            );
        }
    }
}

#[test]
fn prop_slice_resident_bit_exact_vs_baseline_shards_1_to_8() {
    // Slice-resident sharded serving vs the single-threaded baseline
    // (`TableSet::pool`), across shard counts 1..=8 and every format,
    // with whole-table placement so the exactness contract applies to
    // every segment: the outputs must match *bit for bit*. This is the
    // ownership-model check — the engine consumed its set and serves
    // purely from its slices.
    let mut rng = Rng::new(0x51CE);
    for shards in 1..=8usize {
        for fmt in 0..5 {
            let num_tables = 1 + rng.below(3);
            let rows = 8 + rng.below(64);
            let dim = [4usize, 8, 16][rng.below(3)];
            let seed = 0xB00 + (shards * 31 + fmt) as u64;
            let reference = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
            let engine = ShardedEngine::start(
                TableSet::new(build_tables(seed, fmt, num_tables, rows, dim)),
                &ShardConfig {
                    num_shards: shards,
                    small_table_rows: usize::MAX, // whole tables: exactness everywhere
                    ..Default::default()
                },
            );
            for _ in 0..6 {
                let req = Request {
                    ids: (0..num_tables)
                        .map(|_| adversarial_ids(&mut rng, rows, shards))
                        .collect(),
                };
                let got = engine.lookup(&req);
                for (t, ids) in req.ids.iter().enumerate() {
                    let mut want = vec![0.0f32; dim];
                    reference.pool(t, ids, &mut want);
                    assert_eq!(
                        &got[t * dim..(t + 1) * dim],
                        want.as_slice(),
                        "shards={shards} fmt={fmt} table={t}: must be bit-exact"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_hot_replication_preserves_results_and_accounts_bytes() {
    // Hot-chunk replication spreads whole-table lookups across
    // byte-identical replicas: results stay bit-exact vs the baseline,
    // repeated runs agree bitwise, and the byte accounting adds up.
    let mut rng = Rng::new(0x51CF);
    for case in 0..24u64 {
        let num_tables = 1 + rng.below(4);
        let rows = 8 + rng.below(48);
        let dim = [4usize, 8][rng.below(2)];
        let shards = 2 + rng.below(4);
        let replicate_hot = 1 + rng.below(num_tables);
        let fmt = case as usize % 5;
        let seed = 0xC00 + case * 13;
        let reference = TableSet::new(build_tables(seed, fmt, num_tables, rows, dim));
        let logical = reference.size_bytes();
        let engine = ShardedEngine::start(
            TableSet::new(build_tables(seed, fmt, num_tables, rows, dim)),
            &ShardConfig {
                num_shards: shards,
                small_table_rows: usize::MAX,
                replicate_hot,
                ..Default::default()
            },
        );
        // Replicated tables hold a copy on every shard; the rest on one.
        let mut expected_extra = 0usize;
        for t in 0..num_tables {
            let r = engine.replica_shards(t);
            assert!(r.len() == 1 || r.len() == shards, "case {case} table {t}");
            if r.len() == shards {
                expected_extra += (shards - 1) * reference.table(t).size_bytes();
            }
        }
        assert_eq!(engine.replicated_bytes(), expected_extra, "case {case}");
        assert_eq!(
            engine.shard_bytes().iter().sum::<usize>(),
            logical + expected_extra,
            "case {case}"
        );
        let reqs: Vec<Request> = (0..2 + rng.below(4))
            .map(|_| Request {
                ids: (0..num_tables)
                    .map(|_| adversarial_ids(&mut rng, rows, shards))
                    .collect(),
            })
            .collect();
        let fw = engine.feature_width();
        let mut a = vec![0.0f32; reqs.len() * fw];
        let mut b = vec![1.0f32; reqs.len() * fw];
        engine.lookup_batch_into(&reqs, &mut a);
        engine.lookup_batch_into(&reqs, &mut b);
        assert_eq!(a, b, "case {case}: replica choice must not change results");
        for (slot, req) in reqs.iter().enumerate() {
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; dim];
                reference.pool(t, ids, &mut want);
                assert_eq!(
                    &a[slot * fw + t * dim..slot * fw + (t + 1) * dim],
                    want.as_slice(),
                    "case {case} slot {slot} table {t}"
                );
            }
        }
    }
}

#[test]
fn all_ids_in_one_shard_is_bit_identical_per_format() {
    // The headline adversarial case, pinned explicitly per format: every
    // id inside one chunk -> sharded output == unsharded pool, bitwise.
    for fmt in 0..5 {
        let rows = 64;
        let dim = 16;
        let shards = 4; // chunk 16
        let reference = TableSet::new(build_tables(0xAB0 + fmt as u64, fmt, 2, rows, dim));
        let engine_set = TableSet::new(build_tables(0xAB0 + fmt as u64, fmt, 2, rows, dim));
        let engine = ShardedEngine::start(
            engine_set,
            &ShardConfig { num_shards: shards, small_table_rows: 0, ..Default::default() },
        );
        // Chunk 2 of table 0 (rows 32..48), chunk 0 of table 1.
        let req = Request { ids: vec![vec![40, 32, 47, 40], vec![0, 15, 7]] };
        let got = engine.lookup(&req);
        for (t, ids) in req.ids.iter().enumerate() {
            assert_eq!(engine.partition(t).one_shard_for(ids), Some(if t == 0 { 2 } else { 0 }));
            let mut want = vec![0.0f32; dim];
            reference.pool(t, ids, &mut want);
            assert_eq!(&got[t * dim..(t + 1) * dim], want.as_slice(), "fmt {fmt} table {t}");
        }
    }
}
