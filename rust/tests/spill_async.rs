//! The async spill I/O engine, end to end: startup orphan sweeps after
//! an unclean shutdown (adoption must serve bit-exactly and skip the
//! rewrite), prefetching promotions under budget churn, and concurrent
//! serving while demotions stream in the background — all bit-identical
//! to fully-resident serving.

use std::path::PathBuf;
use std::sync::Arc;

use emberq::coordinator::TableSet;
use emberq::data::trace::Request;
use emberq::quant::AsymQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::table::serial::AnyTable;
use emberq::table::{EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

fn fused_set(num_tables: usize, rows: usize, dim: usize, seed: u64) -> TableSet {
    TableSet::new(
        (0..num_tables)
            .map(|t| {
                let tab = EmbeddingTable::randn_sigma(rows, dim, 0.1, seed + 17 * t as u64);
                AnyTable::Fused(tab.quantize_fused(&AsymQuantizer, 4, ScaleBiasDtype::F16))
            })
            .collect(),
    )
}

fn test_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("emberq_spill_async_{name}_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// Unclean-shutdown simulation: a previous run's spill files survive as
/// orphans (plus a half-written `*.tmp` and a corrupt stray). The next
/// startup must adopt the valid ones — first demotion then skips the
/// write entirely — delete the garbage, count both, and serve the
/// re-adopted bytes bit-exactly.
#[test]
fn orphan_sweep_recovers_an_unclean_shutdown() {
    let dir = test_dir("sweep");
    let seed = 0xA51C;
    let reference = fused_set(3, 120, 8, seed);
    let cfg = ShardConfig {
        num_shards: 2,
        small_table_rows: usize::MAX, // 3 whole tables -> 3 cells
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };

    // "Previous run": spill everything, then impersonate a crash by
    // copying every spill file to an orphan name under a dead run
    // token (a clean drop deletes the engine's own files; the copies
    // survive exactly like files orphaned by a kill -9 would have).
    {
        let engine = ShardedEngine::start(fused_set(3, 120, 8, seed), &cfg);
        assert_eq!(engine.spill_all().unwrap(), 3);
        let mut orphaned = 0usize;
        for (i, entry) in std::fs::read_dir(&dir).unwrap().flatten().enumerate() {
            let path = entry.path();
            if path.extension().is_some_and(|e| e == "spill") {
                std::fs::copy(&path, dir.join(format!("slice-0-{i}.spill"))).unwrap();
                orphaned += 1;
            }
        }
        assert_eq!(orphaned, 3, "every cell must have produced a spill file");
    }
    std::fs::write(dir.join("slice-0-90.spill.tmp"), b"torn demote write").unwrap();
    std::fs::write(dir.join("slice-0-91.spill"), b"corrupt stray").unwrap();
    std::fs::write(dir.join("operator-notes.txt"), b"not ours").unwrap();

    // "Recovery run": same model, same directory.
    let engine = ShardedEngine::start(fused_set(3, 120, 8, seed), &cfg);
    let stats = engine.store_stats().expect("spill machinery active");
    assert_eq!(stats.orphans_adopted, 3, "every orphan matches a carved cell");
    assert_eq!(stats.orphans_deleted, 2, "tmp + corrupt stray deleted");
    assert_eq!(stats.spill_write_bytes, 0);
    assert!(dir.join("operator-notes.txt").exists(), "foreign files untouched");
    // Per-shard attribution flows into ShardStats; the shard-less
    // deletion total is reported on shard 0.
    let per_shard = engine.shard_stats();
    assert_eq!(per_shard.iter().map(|s| s.orphans_adopted).sum::<u64>(), 3);
    assert_eq!(per_shard[0].orphans_deleted, 2);
    assert_eq!(per_shard.iter().skip(1).map(|s| s.orphans_deleted).sum::<u64>(), 0);
    // The payoff: demoting everything writes nothing (the adopted files
    // already satisfy the write-once step)...
    assert_eq!(engine.spill_all().unwrap(), 3);
    assert_eq!(
        engine.store_stats().unwrap().spill_write_bytes,
        0,
        "adopted files must spare the serialization"
    );
    // ...and serving from the re-adopted files is bit-exact.
    let req = Request { ids: vec![vec![0, 119, 60], vec![7, 7], vec![13]] };
    let got = engine.lookup(&req);
    let mut want = vec![0.0f32; 3 * 8];
    for (t, ids) in req.ids.iter().enumerate() {
        reference.pool(t, ids, &mut want[t * 8..(t + 1) * 8]);
    }
    assert_eq!(got, want, "re-adopted spill files must serve bit-exactly");
    assert_eq!(engine.store_stats().unwrap().spill_errors, 0);
    drop(engine);
    let _ = std::fs::remove_dir_all(&dir);
}

/// A content change between runs must NOT be adopted: the sweep hash-
/// matches payloads, so stale orphans from a different model are
/// deleted, never served.
#[test]
fn orphan_sweep_rejects_stale_content() {
    let dir = test_dir("stale");
    let cfg = ShardConfig {
        num_shards: 2,
        small_table_rows: usize::MAX,
        spill_dir: Some(dir.clone()),
        ..Default::default()
    };
    {
        let engine = ShardedEngine::start(fused_set(1, 64, 8, 0xBAD), &cfg);
        engine.spill_all().unwrap();
        for entry in std::fs::read_dir(&dir).unwrap().flatten() {
            if entry.path().extension().is_some_and(|e| e == "spill") {
                std::fs::copy(entry.path(), dir.join("slice-0-0.spill")).unwrap();
            }
        }
    }
    // Same shape, different weights: the orphan's range matches but its
    // payload hash cannot.
    let reference = fused_set(1, 64, 8, 0x600D);
    let engine = ShardedEngine::start(fused_set(1, 64, 8, 0x600D), &cfg);
    let stats = engine.store_stats().unwrap();
    assert_eq!(stats.orphans_adopted, 0, "stale content must not be adopted");
    assert_eq!(stats.orphans_deleted, 1);
    engine.spill_all().unwrap();
    let req = Request { ids: vec![vec![0, 63, 31]] };
    let mut want = vec![0.0f32; 8];
    reference.pool(0, &req.ids[0], &mut want);
    assert_eq!(engine.lookup(&req), want, "the fresh model's bytes serve");
    drop(engine);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Budgeted serving with the async engine fully lit — overlapping
/// segment prefetches (row-wise chunks), the heat-driven warmer, and
/// background demotions — must stay bit-identical to the unsharded pool
/// and at-or-under budget at rest, across spill_all churn.
#[test]
fn async_budgeted_serving_is_bit_identical_and_within_budget() {
    let seed = 0xA5F0;
    let reference = fused_set(2, 96, 8, seed);
    let logical = reference.size_bytes();
    let budget = logical / 3;
    let engine = ShardedEngine::start(
        fused_set(2, 96, 8, seed),
        &ShardConfig {
            num_shards: 4,
            small_table_rows: 0, // row-wise chunks: spanning segments prefetch
            resident_budget: Some(budget),
            spill_io_threads: 2,
            prefetch_window: 2,
            ..Default::default()
        },
    );
    let fw = engine.feature_width();
    let mut rng = Rng::new(0xA5F1);
    for round in 0..8 {
        if round % 2 == 1 {
            // Everything to disk: the next spanning request promotes
            // several spilled chunks per segment -> overlapping reads.
            engine.spill_all().unwrap();
        }
        if round == 4 {
            // A rebalance pass ticks the store's heat clock, which also
            // drives the prefetch_window warmer.
            let _ = engine.rebalance_once();
        }
        let reqs: Vec<Request> = (0..3)
            .map(|_| {
                Request {
                    ids: (0..2)
                        .map(|_| {
                            // Spanning id lists: hit all four chunks.
                            (0..12).map(|_| rng.below(96) as u32).collect()
                        })
                        .collect(),
                }
            })
            .collect();
        let mut out = vec![1.0f32; reqs.len() * fw];
        engine.lookup_batch_into(&reqs, &mut out);
        for (slot, req) in reqs.iter().enumerate() {
            for (t, ids) in req.ids.iter().enumerate() {
                let mut want = vec![0.0f32; 8];
                reference.pool(t, ids, &mut want);
                assert_eq!(
                    &out[slot * fw + t * 8..slot * fw + (t + 1) * 8],
                    want.as_slice(),
                    "round {round} slot {slot} table {t}"
                );
            }
        }
        let resident: usize = engine.shard_bytes().iter().sum();
        assert!(
            resident <= budget,
            "round {round}: resident {resident} over budget {budget}"
        );
    }
    let stats = engine.store_stats().unwrap();
    assert_eq!(stats.spill_errors, 0);
    assert!(stats.promotions > 0 && stats.demotions > 0);
    assert!(
        stats.prefetches > 0,
        "spanning segments over spilled chunks must issue overlapping reads"
    );
    assert!(stats.demote_stream_bytes > 0, "demotions must stream their payloads");
    // Per-shard prefetch counters reconcile with the total.
    let per_shard: u64 = engine.shard_stats().iter().map(|s| s.prefetches).sum();
    assert_eq!(per_shard, stats.prefetches);
}

/// Concurrency hammer: many client threads serve through a tight budget
/// (tier churn on every batch) while spill_all storms run in between —
/// every single lookup must match the unsharded pool bit for bit and
/// nothing may deadlock.
#[test]
fn concurrent_clients_survive_background_tier_churn_bit_exactly() {
    let seed = 0xA5E0;
    let reference = Arc::new(fused_set(3, 80, 8, seed));
    let logical = reference.size_bytes();
    let engine = Arc::new(ShardedEngine::start(
        fused_set(3, 80, 8, seed),
        &ShardConfig {
            num_shards: 2,
            small_table_rows: usize::MAX,
            resident_budget: Some(logical / 2),
            spill_io_threads: 1, // a single I/O lane maximizes queueing
            ..Default::default()
        },
    ));
    let threads: Vec<_> = (0..4)
        .map(|k| {
            let engine = Arc::clone(&engine);
            let reference = Arc::clone(&reference);
            std::thread::spawn(move || {
                let mut rng = Rng::new(0xA5E1 + k as u64);
                for i in 0..30 {
                    if i % 10 == 9 {
                        engine.spill_all().expect("demote-all under load");
                    }
                    let req = Request {
                        ids: (0..3)
                            .map(|_| {
                                (0..1 + rng.below(4)).map(|_| rng.below(80) as u32).collect()
                            })
                            .collect(),
                    };
                    let got = engine.lookup(&req);
                    for (t, ids) in req.ids.iter().enumerate() {
                        let mut want = vec![0.0f32; 8];
                        reference.pool(t, ids, &mut want);
                        assert_eq!(
                            &got[t * 8..(t + 1) * 8],
                            want.as_slice(),
                            "thread {k} iter {i} table {t}"
                        );
                    }
                }
            })
        })
        .collect();
    for t in threads {
        t.join().expect("client thread must not panic");
    }
    let stats = engine.store_stats().unwrap();
    assert_eq!(stats.spill_errors, 0);
    assert!(stats.demotions > 0 && stats.promotions > 0);
}
