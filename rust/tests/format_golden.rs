//! Golden byte-level tests for the two on-disk containers.
//!
//! `docs/formats.md` is the *normative* spec for `EMBQTBL2` and
//! `EMBQSPL2`; these tests re-derive every header offset, field width,
//! the versioned format tag, and the checksum from that prose —
//! independently of the writer code in `table::serial` and
//! `shard::store` — so an implementation change that silently shifts a
//! byte fails here, not in a reader two releases later. The layouts are
//! frozen: a legitimate format change must bump the magic (`EMBQTBL3`,
//! ...) and get new goldens, not edit these.

use std::fs;

use emberq::quant::GreedyQuantizer;
use emberq::shard::{SliceStore, SpillConfig, TableSlice};
use emberq::table::serial::{self, AnyTable};
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};

/// Independent FNV-1a-64, straight from the constants in
/// docs/formats.md — deliberately NOT `serial::fnv1a64`.
fn fnv1a64_ref(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

fn u64_at(b: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(b[off..off + 8].try_into().unwrap())
}

fn u16_at(b: &[u8], off: usize) -> u16 {
    u16::from_le_bytes(b[off..off + 2].try_into().unwrap())
}

#[test]
fn fnv_reference_vectors_from_the_spec() {
    assert_eq!(fnv1a64_ref(b""), 0xcbf2_9ce4_8422_2325);
    assert_eq!(fnv1a64_ref(b"a"), 0xaf63_dc4c_8601_ec8c);
    assert_eq!(fnv1a64_ref(b"foobar"), 0x8594_4171_f739_67e8);
}

#[test]
fn format_tags_match_the_spec_vectors() {
    // Spec formula: (layout_revision << 12) | (kind << 8) | detail,
    // detail = 0 for FP32, (nbits << 4) | sb for fused,
    // (scheme << 4) | sb for codebook; sb: 0 = f32, 1 = f16. The
    // vectors below are computed by hand from that prose at layout
    // revision 1 — they must never drift under a same-magic change.
    let q = GreedyQuantizer::default();
    let t = EmbeddingTable::randn(8, 6, 81);
    let vectors: [(AnyTable, u16); 5] = [
        (AnyTable::F32(t.clone()), 0x1000),
        (AnyTable::Fused(t.quantize_fused(&q, 4, ScaleBiasDtype::F16)), 0x1141),
        (AnyTable::Fused(t.quantize_fused(&q, 8, ScaleBiasDtype::F32)), 0x1180),
        (
            AnyTable::Codebook(t.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32)),
            0x1200,
        ),
        (
            AnyTable::Codebook(
                t.quantize_codebook(CodebookKind::TwoTier { k: 3 }, ScaleBiasDtype::F16),
            ),
            0x1211,
        ),
    ];
    for (table, want) in &vectors {
        assert_eq!(serial::format_tag(table), *want, "{want:#06x}");
    }
}

#[test]
fn embqtbl2_fp32_layout_matches_the_spec() {
    // kind 0: [magic 8][kind 1][revision 1][rows u64][dim u64]
    // [rows×dim f32].
    let t = EmbeddingTable::randn(5, 3, 77);
    let mut buf = Vec::new();
    serial::write_f32(&mut buf, &t).unwrap();

    assert_eq!(buf.len(), 8 + 1 + 1 + 8 + 8 + 5 * 3 * 4, "no padding anywhere");
    assert_eq!(&buf[0..8], b"EMBQTBL2");
    assert_eq!(buf[8], 0, "kind 0 = FP32");
    assert_eq!(buf[9], 1, "layout revision at [9]");
    assert_eq!(u64_at(&buf, 10), 5, "rows at [10..18)");
    assert_eq!(u64_at(&buf, 18), 3, "dim at [18..26)");
    // Payload: row-major little-endian f32 starting at byte 26.
    for r in 0..5 {
        for d in 0..3 {
            let off = 26 + (r * 3 + d) * 4;
            let got = f32::from_le_bytes(buf[off..off + 4].try_into().unwrap());
            assert_eq!(got.to_bits(), t.row(r)[d].to_bits(), "row {r} dim {d}");
        }
    }
}

#[test]
fn embqtbl2_fused_layout_matches_the_spec() {
    // kind 1: [magic 8][kind 1][revision 1][rows u64][dim u64]
    // [nbits u8][sb u8][rows×row_bytes]. Odd dim exercises the
    // ceil(dim/2) packing.
    let q = GreedyQuantizer::default();
    let t = EmbeddingTable::randn(7, 5, 78).quantize_fused(&q, 4, ScaleBiasDtype::F16);
    let mut buf = Vec::new();
    serial::write_fused(&mut buf, &t).unwrap();

    // row_bytes re-derived from the spec, not from the table:
    // packed = ceil(5/2) = 3, f16 tail = 4 → 7 bytes per row.
    let row_bytes = (5 + 1) / 2 + 4;
    assert_eq!(buf.len(), 8 + 1 + 1 + 8 + 8 + 1 + 1 + 7 * row_bytes);
    assert_eq!(&buf[0..8], b"EMBQTBL2");
    assert_eq!(buf[8], 1, "kind 1 = Fused");
    assert_eq!(buf[9], 1, "layout revision at [9]");
    assert_eq!(u64_at(&buf, 10), 7, "rows at [10..18)");
    assert_eq!(u64_at(&buf, 18), 5, "dim at [18..26)");
    assert_eq!(buf[26], 4, "nbits at [26]");
    assert_eq!(buf[27], 1, "sb tag at [27]: 1 = f16");
    assert_eq!(&buf[28..], t.data(), "payload is the raw fused rows, verbatim");

    // And with f32 scale/bias the tail widens to 8 bytes, nothing else
    // moves.
    let t32 = EmbeddingTable::randn(7, 5, 79).quantize_fused(&q, 8, ScaleBiasDtype::F32);
    let mut buf32 = Vec::new();
    serial::write_fused(&mut buf32, &t32).unwrap();
    assert_eq!(buf32.len(), 28 + 7 * (5 + 8), "8-bit packs one code per byte");
    assert_eq!(buf32[26], 8);
    assert_eq!(buf32[27], 0, "sb tag 0 = f32");

    // Round trip through the reader: bit-identical table.
    let back = serial::read_any(&mut buf.as_slice()).unwrap();
    match back {
        AnyTable::Fused(b) => assert_eq!(b.data(), t.data()),
        other => panic!("wrong kind decoded: {} rows", other.rows()),
    }
}

#[test]
fn embqtbl2_codebook_layout_matches_the_spec() {
    // kind 2: [magic 8][kind 1][revision 1][rows u64][dim u64]
    // [scheme u8][sb u8][k u64][rows×ceil(dim/2) codes]
    // [books×16 f32 entries][two-tier only: rows×u32 cluster ids],
    // books = k for two-tier, rows for rowwise.
    let t = EmbeddingTable::randn(10, 6, 82);
    let cb = t.quantize_codebook(CodebookKind::TwoTier { k: 3 }, ScaleBiasDtype::F16);
    let mut buf = Vec::new();
    serial::write_codebook(&mut buf, &cb).unwrap();

    // Every length re-derived from the spec: header 36, nibble-packed
    // codes 10×3, three 16-entry f32 books, ten u32 cluster ids.
    assert_eq!(buf.len(), 36 + 10 * 3 + 3 * 16 * 4 + 10 * 4, "no padding anywhere");
    assert_eq!(&buf[0..8], b"EMBQTBL2");
    assert_eq!(buf[8], 2, "kind 2 = Codebook");
    assert_eq!(buf[9], 1, "layout revision at [9]");
    assert_eq!(u64_at(&buf, 10), 10, "rows at [10..18)");
    assert_eq!(u64_at(&buf, 18), 6, "dim at [18..26)");
    assert_eq!(buf[26], 1, "scheme at [26]: 1 = two-tier");
    assert_eq!(buf[27], 1, "sb tag at [27]: 1 = f16");
    assert_eq!(u64_at(&buf, 28), 3, "k at [28..36)");
    for i in 0..10 {
        assert_eq!(&buf[36 + i * 3..36 + (i + 1) * 3], cb.codes_of_row(i), "codes row {i}");
    }

    // Rowwise: scheme 0, k recorded as 0, one book per row, no cluster
    // ids.
    let rw = EmbeddingTable::randn(4, 5, 83)
        .quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32);
    let mut rbuf = Vec::new();
    serial::write_codebook(&mut rbuf, &rw).unwrap();
    assert_eq!(rbuf.len(), 36 + 4 * 3 + 4 * 16 * 4);
    assert_eq!(rbuf[26], 0, "scheme 0 = rowwise");
    assert_eq!(rbuf[27], 0, "sb tag 0 = f32");
    assert_eq!(u64_at(&rbuf, 28), 0, "rowwise records k = 0");

    // Round trip: the decoded table reconstructs bit-identically.
    let back = serial::read_any(&mut buf.as_slice()).unwrap();
    match back {
        AnyTable::Codebook(b) => {
            assert_eq!(b.dequantize().data(), cb.dequantize().data());
        }
        other => panic!("wrong kind decoded: {} rows", other.rows()),
    }
}

#[test]
fn embqspl2_layout_and_checksum_match_the_spec() {
    // [magic 8][global_lo u64][global_hi u64][fmt_tag u16 @24]
    // [payload_len u64 @26][fnv1a64 u64 @34][payload = verbatim
    // EMBQTBL2].
    let q = GreedyQuantizer::default();
    let table = EmbeddingTable::randn(12, 4, 80).quantize_fused(&q, 4, ScaleBiasDtype::F16);
    // The slice covers global rows [3, 12) of some larger table — the
    // header must carry the range, not just a length.
    let whole = AnyTable::Fused(table);
    let slice = TableSlice::cut(&whole, 3..12);
    let mut expect_payload = Vec::new();
    serial::write_any(&mut expect_payload, slice.table()).unwrap();

    let dir = std::env::temp_dir().join(format!("emberq-golden-{}", std::process::id()));
    fs::create_dir_all(&dir).unwrap();
    let store = SliceStore::new(
        &SpillConfig {
            dir: dir.clone(),
            resident_budget: usize::MAX,
            cleanup_dir: true,
            io_threads: 0,
            prefetch_window: 0,
        },
        1,
        false,
    )
    .unwrap();
    let _cell = store.admit(0, 0, slice);
    assert_eq!(store.demote_all().unwrap(), 1);

    // Exactly one spill file, named per the spec's scheme.
    let files: Vec<_> = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|x| x == "spill"))
        .collect();
    assert_eq!(files.len(), 1, "one admitted slice, one spill file");
    let name = files[0].file_name().unwrap().to_str().unwrap();
    assert!(
        name.starts_with("slice-") && name.ends_with(".spill"),
        "naming scheme slice-<token>-<seq>.spill, got {name}"
    );
    assert_eq!(name.matches('-').count(), 2, "token and seq, dash-separated: {name}");

    let bytes = fs::read(&files[0]).unwrap();
    assert_eq!(&bytes[0..8], b"EMBQSPL2");
    assert_eq!(u64_at(&bytes, 8), 3, "global_lo at [8..16)");
    assert_eq!(u64_at(&bytes, 16), 12, "global_hi at [16..24) is one past the end");
    // fmt_tag computed by hand from the spec: revision 1, kind 1
    // (fused), nbits 4, sb 1 (f16) → 0x1141.
    assert_eq!(u16_at(&bytes, 24), 0x1141, "fmt_tag at [24..26)");
    assert_eq!(u64_at(&bytes, 26), (bytes.len() - 42) as u64, "payload_len at [26..34)");
    assert_eq!(
        u64_at(&bytes, 34),
        fnv1a64_ref(&bytes[42..]),
        "checksum at [34..42) is FNV-1a-64 of the payload only"
    );
    assert_eq!(&bytes[42..], &expect_payload[..], "payload is the slice's table, verbatim");
    // The payload really is a self-contained EMBQTBL2 container, and
    // its own header agrees with the spill header's fmt_tag.
    let decoded = serial::read_any(&mut &bytes[42..]).unwrap();
    assert_eq!(decoded.rows(), 9);
    assert_eq!(serial::format_tag(&decoded), 0x1141, "container and spill tags agree");
    // No .tmp leftovers: the write protocol renames atomically.
    let tmps = fs::read_dir(&dir)
        .unwrap()
        .filter_map(|e| e.ok())
        .filter(|e| e.path().to_string_lossy().ends_with(".tmp"))
        .count();
    assert_eq!(tmps, 0);

    drop(store); // cleanup_dir removes the directory
    assert!(!dir.exists(), "cleanup_dir honors its contract");
}
