//! SIMD-vs-scalar oracle: the scalar kernels are the bit-exactness
//! reference, and every other backend must reproduce them *exactly* —
//! `f32::to_bits` equality, never a tolerance. The property sweep
//! hand-rolls its cases from the crate's own deterministic
//! [`emberq::util::Rng`] (the crate is dependency-free, so no proptest):
//! all formats × a dim ladder straddling every SIMD lane width and the
//! cache-blocking threshold × empty segments × duplicate and
//! out-of-order ids.
//!
//! On a CPU with no SIMD backend — or under `EMBERQ_FORCE_SCALAR`,
//! where the engines legitimately resolve to scalar — the sweep skips
//! and says so loudly; the CI kernel matrix supplies the real AVX2 leg
//! and pins which arm ran via `EMBERQ_EXPECT_BACKEND`.

use emberq::coordinator::{EmbeddingServer, ServerConfig, TableSet};
use emberq::data::trace::{Request, RequestTrace, TraceConfig};
use emberq::quant::AsymQuantizer;
use emberq::shard::{ShardConfig, ShardedEngine};
use emberq::sls::{
    backend, sls_mean_fused_with, sls_weighted_f32_with, sls_weighted_fused_with, KernelBackend,
    SlsArgs, SlsTable,
};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

/// The backend under test, or `None` (loudly) when there is nothing
/// beyond scalar to compare against. Uses [`backend::active`] rather
/// than raw CPU detection so the suite skips on CI's forced-scalar leg
/// too: there the engines resolve `EMBERQ_FORCE_SCALAR` down to scalar,
/// and asserting they picked a SIMD backend would be asserting a lie.
fn simd_backend() -> Option<KernelBackend> {
    let b = backend::active();
    if b == KernelBackend::Scalar {
        eprintln!(
            "note: no SIMD backend on this CPU (or EMBERQ_FORCE_SCALAR is set) — \
             oracle sweep skipped; scalar is its own reference"
        );
        None
    } else {
        Some(b)
    }
}

/// Dims straddling every interesting boundary: scalar-only (< any lane
/// width), exact lane multiples (8 = one AVX2 register, 16, 64), every
/// tail residue class around them, odd/prime dims for the nibble
/// even/odd split, and one past the cache-blocking threshold (4096).
const DIMS: &[usize] = &[1, 2, 3, 5, 7, 8, 9, 15, 16, 17, 31, 33, 64, 100, 513, 4100];

/// Random SLS args with empty segments, duplicates, and repeats mixed
/// in. Returns `(indices, lengths)`.
fn random_args(rng: &mut Rng, rows: usize, segments: usize) -> (Vec<u32>, Vec<u32>) {
    let mut indices = Vec::new();
    let mut lengths = Vec::with_capacity(segments);
    for s in 0..segments {
        // Segment 0 is always empty; others are empty 1 time in 5.
        let len = if s == 0 || rng.below(5) == 0 { 0 } else { 1 + rng.below(9) };
        lengths.push(len as u32);
        for _ in 0..len {
            indices.push(rng.below(rows) as u32);
        }
    }
    (indices, lengths)
}

/// Assert two pooled outputs are bit-identical, with a useful failure.
fn assert_bits_eq(want: &[f32], got: &[f32], what: &str) {
    assert_eq!(want.len(), got.len(), "{what}: length mismatch");
    for (i, (w, g)) in want.iter().zip(got).enumerate() {
        assert_eq!(
            w.to_bits(),
            g.to_bits(),
            "{what}: bit divergence at element {i}: scalar {w:?} vs simd {g:?}"
        );
    }
}

#[test]
fn simd_matches_scalar_on_every_format_and_dim() {
    let Some(simd) = simd_backend() else { return };
    let q = AsymQuantizer;
    let mut rng = Rng::new(0x0_51D_0_2AC1E);
    for &d in DIMS {
        // Keep the 4100-dim case cheap: fewer rows, fewer segments.
        let (rows, segments) = if d >= 4096 { (12, 3) } else { (57, 7) };
        let master = EmbeddingTable::randn(rows, d, 0xBA5E ^ d as u64);
        let mut tables: Vec<(String, AnyTable)> = vec![
            ("f32".into(), AnyTable::F32(master.clone())),
            ("cb-rowwise".into(), {
                AnyTable::Codebook(master.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32))
            }),
            ("cb-twotier".into(), {
                AnyTable::Codebook(
                    master.quantize_codebook(CodebookKind::TwoTier { k: 3 }, ScaleBiasDtype::F16),
                )
            }),
        ];
        for nbits in [4u32, 8] {
            for sb in [ScaleBiasDtype::F16, ScaleBiasDtype::F32] {
                let name = format!("i{nbits}-{sb:?}");
                tables.push((name, AnyTable::Fused(master.quantize_fused(&q, nbits, sb))));
            }
        }

        for trial in 0..4 {
            let (indices, lengths) = random_args(&mut rng, rows, segments);
            for (name, any) in &tables {
                let view = match any {
                    AnyTable::F32(t) => SlsTable::F32(t),
                    AnyTable::Fused(t) => SlsTable::Fused(t),
                    AnyTable::Codebook(t) => SlsTable::Codebook(t),
                };
                let args = SlsArgs::new(&indices, &lengths, rows).unwrap();
                let mut want = vec![0.0f32; segments * d];
                let mut got = want.clone();
                view.sls_with(KernelBackend::Scalar, &args, &mut want);
                view.sls_with(simd, &args, &mut got);
                assert_bits_eq(&want, &got, &format!("{name} d={d} trial={trial}"));
            }
        }
    }
}

#[test]
fn simd_matches_scalar_on_weighted_and_mean_variants() {
    let Some(simd) = simd_backend() else { return };
    let q = AsymQuantizer;
    let mut rng = Rng::new(0x3EE_D5);
    for &d in &[1usize, 7, 8, 16, 33, 100] {
        let rows = 41;
        let master = EmbeddingTable::randn(rows, d, 0xFEED ^ d as u64);
        let fused4 = master.quantize_fused(&q, 4, ScaleBiasDtype::F16);
        let fused8 = master.quantize_fused(&q, 8, ScaleBiasDtype::F32);
        for trial in 0..4 {
            let (indices, lengths) = random_args(&mut rng, rows, 5);
            let weights: Vec<f32> =
                indices.iter().map(|_| rng.uniform_in(-1.5, 1.5) as f32).collect();
            let args = SlsArgs::new(&indices, &lengths, rows).unwrap();
            let mut want = vec![0.0f32; 5 * d];
            let mut got = want.clone();

            sls_weighted_f32_with(KernelBackend::Scalar, &master, &args, &weights, &mut want);
            sls_weighted_f32_with(simd, &master, &args, &weights, &mut got);
            assert_bits_eq(&want, &got, &format!("weighted-f32 d={d} trial={trial}"));

            for (name, fused) in [("i4", &fused4), ("i8", &fused8)] {
                sls_weighted_fused_with(KernelBackend::Scalar, fused, &args, &weights, &mut want);
                sls_weighted_fused_with(simd, fused, &args, &weights, &mut got);
                assert_bits_eq(&want, &got, &format!("weighted-{name} d={d} trial={trial}"));

                sls_mean_fused_with(KernelBackend::Scalar, fused, &args, &mut want);
                sls_mean_fused_with(simd, fused, &args, &mut got);
                assert_bits_eq(&want, &got, &format!("mean-{name} d={d} trial={trial}"));
            }
        }
    }
}

/// Build the mixed-format table set used by the serving-path tests:
/// rows=61 with shard counts 3/5/8 puts chunk boundaries at non-lane-
/// aligned, non-equal offsets, so segment pooling crosses misaligned
/// chunk starts.
fn mixed_set(rows: usize, dim: usize) -> TableSet {
    let q = AsymQuantizer;
    let mk = |seed: u64| EmbeddingTable::randn(rows, dim, seed);
    TableSet::new(vec![
        AnyTable::F32(mk(11)),
        AnyTable::Fused(mk(22).quantize_fused(&q, 4, ScaleBiasDtype::F16)),
        AnyTable::Fused(mk(33).quantize_fused(&q, 8, ScaleBiasDtype::F32)),
        AnyTable::Codebook(mk(44).quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32)),
    ])
}

fn small_trace(rows: usize, tables: usize) -> RequestTrace {
    RequestTrace::generate(&TraceConfig {
        requests: 60,
        num_tables: tables,
        rows,
        mean_pool: 6,
        zipf_alpha: 1.05,
        seed: 0xD00D_1E5,
    })
}

#[test]
fn sharded_engine_is_backend_invariant_at_every_shard_count() {
    let Some(simd) = simd_backend() else { return };
    let (rows, dim, tables) = (61usize, 33usize, 4usize);
    let trace = small_trace(rows, tables);
    for &shards in &[1usize, 2, 3, 5, 8] {
        let cfg = |kb| ShardConfig {
            num_shards: shards,
            small_table_rows: 0,
            kernel_backend: Some(kb),
            ..ShardConfig::default()
        };
        let scalar = ShardedEngine::start(mixed_set(rows, dim), &cfg(KernelBackend::Scalar));
        let fast = ShardedEngine::start(mixed_set(rows, dim), &cfg(simd));
        assert_eq!(scalar.kernel_backend(), KernelBackend::Scalar);
        assert_eq!(fast.kernel_backend(), simd);
        for (i, req) in trace.requests.iter().enumerate() {
            let want = scalar.lookup(req);
            let got = fast.lookup(req);
            assert_bits_eq(&want, &got, &format!("shards={shards} request={i}"));
        }
    }
}

#[test]
fn served_trace_is_backend_invariant_end_to_end() {
    let Some(simd) = simd_backend() else { return };
    let (rows, dim, tables) = (61usize, 17usize, 4usize);
    let trace = small_trace(rows, tables);
    let cfg = |kb| ServerConfig {
        num_shards: 3,
        small_table_rows: 0,
        kernel_backend: Some(kb),
        ..ServerConfig::default()
    };
    let scalar = EmbeddingServer::start(mixed_set(rows, dim), cfg(KernelBackend::Scalar));
    let fast = EmbeddingServer::start(mixed_set(rows, dim), cfg(simd));
    for (i, req) in trace.requests.iter().enumerate() {
        assert_bits_eq(&scalar.lookup(req), &fast.lookup(req), &format!("request={i}"));
    }
    // The chosen backend is observable in the per-shard stats.
    let stats = fast.shard_stats().expect("sharded server reports shard stats");
    for st in &stats {
        assert_eq!(st.kernel, Some(simd));
        assert!(st.summary().contains(&format!("kernel={simd}")), "{}", st.summary());
    }
}

#[test]
fn empty_and_degenerate_requests_are_backend_invariant() {
    let Some(simd) = simd_backend() else { return };
    let rows = 19;
    let master = EmbeddingTable::randn(rows, 24, 0xE_0);
    let view = SlsTable::F32(&master);
    // All-empty args: zero segments, and segments that are all empty.
    for (indices, lengths) in [(vec![], vec![]), (vec![], vec![0u32, 0, 0])] {
        let args = SlsArgs::new(&indices, &lengths, rows).unwrap();
        let mut want = vec![7.0f32; lengths.len() * 24];
        let mut got = want.clone();
        view.sls_with(KernelBackend::Scalar, &args, &mut want);
        view.sls_with(simd, &args, &mut got);
        assert_bits_eq(&want, &got, "empty segments");
        assert!(want.iter().all(|&v| v == 0.0), "empty segments must pool to zero");
    }
    // A one-table engine request whose only segment is empty.
    let engine = ShardedEngine::start(
        TableSet::new(vec![AnyTable::F32(master.clone())]),
        &ShardConfig {
            num_shards: 2,
            small_table_rows: 0,
            kernel_backend: Some(simd),
            ..ShardConfig::default()
        },
    );
    let got = engine.lookup(&Request { ids: vec![vec![]] });
    assert!(got.iter().all(|&v| v == 0.0));
}
