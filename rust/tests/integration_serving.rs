//! Integration: the serving stack (router + batcher + worker pool) over
//! every table format, with metrics accounting.

use emberq::coordinator::{BatchPolicy, EmbeddingServer, ServerConfig, TableSet};
use emberq::data::trace::{Request, RequestTrace, TraceConfig};
use emberq::quant::{AsymQuantizer, GreedyQuantizer};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};

fn fp32_tables(n: usize, rows: usize, dim: usize) -> Vec<EmbeddingTable> {
    (0..n)
        .map(|t| EmbeddingTable::randn_sigma(rows, dim, 0.1, 8800 + t as u64))
        .collect()
}

#[test]
fn all_formats_serve_consistent_results() {
    let fp32 = fp32_tables(4, 200, 16);
    let trace = RequestTrace::generate(&TraceConfig {
        requests: 50,
        num_tables: 4,
        rows: 200,
        mean_pool: 5,
        zipf_alpha: 1.1,
        seed: 3,
    });
    // FP32 server is the reference.
    let mk = |tables: Vec<AnyTable>| {
        EmbeddingServer::start(
            TableSet::new(tables),
            ServerConfig { shards: 2, ..Default::default() },
        )
    };
    let ref_server = mk(fp32.iter().cloned().map(AnyTable::F32).collect());
    let int4_server = mk(fp32
        .iter()
        .map(|t| {
            AnyTable::Fused(t.quantize_fused(&GreedyQuantizer::default(), 4, ScaleBiasDtype::F16))
        })
        .collect());
    let cb_server = mk(fp32
        .iter()
        .map(|t| {
            AnyTable::Codebook(t.quantize_codebook(CodebookKind::Rowwise, ScaleBiasDtype::F32))
        })
        .collect());

    for req in trace.requests.iter().take(20) {
        let want = ref_server.lookup(req);
        for (name, server) in [("int4", &int4_server), ("codebook", &cb_server)] {
            let got = server.lookup(req);
            let pool: usize = req.ids.iter().map(Vec::len).sum();
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert!(
                    (w - g).abs() < 0.05 * pool as f32 + 0.05,
                    "{name} diverged at {i}: {w} vs {g}"
                );
            }
        }
    }
}

#[test]
fn int4_serves_from_a_fraction_of_the_bytes() {
    let fp32 = fp32_tables(4, 1000, 64);
    let f32_set = TableSet::new(fp32.iter().cloned().map(AnyTable::F32).collect());
    let int4_set = TableSet::new(
        fp32.iter()
            .map(|t| {
                let f = t.quantize_fused(&GreedyQuantizer::default(), 4, ScaleBiasDtype::F16);
                AnyTable::Fused(f)
            })
            .collect(),
    );
    let ratio = int4_set.size_bytes() as f64 / f32_set.size_bytes() as f64;
    assert!((ratio - 0.140625).abs() < 1e-6, "d=64 FP16 ratio {ratio}"); // paper 14.06%
}

#[test]
fn metrics_account_for_every_request_and_lookup() {
    let fp32 = fp32_tables(3, 100, 8);
    let server = EmbeddingServer::start(
        TableSet::new(
            fp32.iter()
                .map(|t| AnyTable::Fused(t.quantize_fused(&AsymQuantizer, 8, ScaleBiasDtype::F32)))
                .collect(),
        ),
        ServerConfig {
            shards: 3,
            queue_depth: 4,
            batch: BatchPolicy { max_batch: 7, ..Default::default() },
            ..Default::default()
        },
    );
    let trace = RequestTrace::generate(&TraceConfig {
        requests: 33,
        num_tables: 3,
        rows: 100,
        mean_pool: 4,
        zipf_alpha: 1.05,
        seed: 11,
    });
    let m = server.serve_trace(&trace);
    assert_eq!(m.requests, 33);
    assert_eq!(m.lookups as usize, trace.total_lookups());
    assert_eq!(m.batches, 5); // ceil(33/7)
    assert_eq!(m.latency.count(), 33);
    let (p50, _, p99) = m.latency.percentiles();
    assert!(p50 <= p99);
    assert!(m.throughput() > 0.0);
}

#[test]
fn empty_pools_and_hot_rows() {
    // Degenerate requests: all-empty pools, and all requests hammering
    // one row.
    let fp32 = fp32_tables(2, 10, 4);
    let server = EmbeddingServer::start(
        TableSet::new(fp32.iter().cloned().map(AnyTable::F32).collect()),
        ServerConfig { shards: 2, ..Default::default() },
    );
    let empty = Request { ids: vec![vec![], vec![]] };
    assert!(server.lookup(&empty).iter().all(|&v| v == 0.0));
    let hot = Request { ids: vec![vec![3; 50], vec![3; 50]] };
    let out = server.lookup(&hot);
    for j in 0..4 {
        let want = 50.0 * fp32[0].row(3)[j];
        assert!((out[j] - want).abs() < 1e-3, "{} vs {}", out[j], want);
    }
}

#[test]
fn many_shards_more_than_tables() {
    // More shards than tables must still work (idle shards).
    let fp32 = fp32_tables(2, 50, 8);
    let server = EmbeddingServer::start(
        TableSet::new(fp32.iter().cloned().map(AnyTable::F32).collect()),
        ServerConfig { shards: 8, ..Default::default() },
    );
    let req = Request { ids: vec![vec![1, 2, 3], vec![4]] };
    assert_eq!(server.lookup(&req).len(), 16);
}
