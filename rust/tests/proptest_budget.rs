//! Property tests for the precision-budget solver (`quant::budget`) and
//! the online re-quantization swap path (hand-rolled property loops —
//! the crate builds offline with no test-framework dependencies).
//!
//! Four contracts:
//!
//! * **Budget fit** — [`solve`] never spends past the byte budget, and
//!   returns exactly one assignment per group whose bytes sum to the
//!   reported total. Any budget at or above [`uniform_int4_bytes`] is
//!   feasible (the codebook admission rule keeps every ladder's floor
//!   at or below the int4 bytes).
//! * **Monotonicity** — a bigger budget never *downgrades* a group:
//!   the greedy walk takes a prefix of one fixed global step order, so
//!   per-group bytes are non-decreasing in the budget.
//! * **Flat-heat degeneracy** — with uniform heat and the budget pinned
//!   to uniform int4 bytes, the solver reproduces the paper's baseline
//!   exactly: every group lands on `int4 (FP16)`, spending the whole
//!   budget.
//! * **Online ≡ offline** — after [`ShardedEngine::requantize_to`],
//!   every row serves bit-identically to rebuilding the same chunk
//!   offline with [`budget::build_table`] — including codebook chunk
//!   targets and after the spill tier churns the swapped slices to
//!   disk and back.
//!
//! [`solve`]: emberq::quant::budget::solve
//! [`uniform_int4_bytes`]: emberq::quant::budget::uniform_int4_bytes
//! [`ShardedEngine::requantize_to`]: emberq::shard::ShardedEngine::requantize_to
//! [`budget::build_table`]: emberq::quant::budget::build_table

use emberq::coordinator::{FormatTag, TableSet};
use emberq::data::trace::Request;
use emberq::quant::budget::{self, GroupSpec};
use emberq::quant::GreedyQuantizer;
use emberq::shard::{GroupAssignment, ShardConfig, ShardedEngine};
use emberq::table::serial::AnyTable;
use emberq::table::{CodebookKind, EmbeddingTable, ScaleBiasDtype};
use emberq::util::Rng;

const INT4: FormatTag = FormatTag::Fused { nbits: 4, scale_bias: ScaleBiasDtype::F16 };

/// Random gaussian row-groups with arbitrary small shapes and random
/// positive heat — the spec generator for the solver-contract tests.
fn random_specs(rng: &mut Rng) -> Vec<GroupSpec> {
    let n = 1 + rng.below(4);
    (0..n)
        .map(|t| {
            let rows = [32usize, 64, 96, 128][rng.below(4)];
            let dim = [4usize, 8, 16][rng.below(3)];
            let seed = rng.next_u64();
            GroupSpec {
                table: t,
                chunk: None,
                heat: rng.uniform_in(0.5, 100.0),
                data: EmbeddingTable::randn(rows, dim, seed),
            }
        })
        .collect()
}

#[test]
fn prop_solve_fits_budget_and_assigns_every_group() {
    const CASES: usize = 60;
    let q = GreedyQuantizer::default();
    let mut rng = Rng::new(0xB0D6);
    for case in 0..CASES {
        let specs = random_specs(&mut rng);
        let uniform = budget::uniform_int4_bytes(&specs);
        // Anywhere in [uniform, 2 * uniform]: always feasible, because
        // each ladder's cheapest level costs at most its int4 bytes.
        let budget_bytes = uniform + rng.below(uniform + 1);
        let plan = budget::solve(&specs, budget_bytes, &q)
            .unwrap_or_else(|e| panic!("case {case}: budget {budget_bytes} B must fit: {e}"));
        assert!(
            plan.total_bytes <= budget_bytes,
            "case {case}: spent {} B over the {budget_bytes} B budget",
            plan.total_bytes
        );
        assert_eq!(plan.assignments.len(), specs.len(), "case {case}: one per group");
        for (a, s) in plan.assignments.iter().zip(&specs) {
            assert_eq!((a.table, a.chunk), (s.table, s.chunk), "case {case}: spec order");
        }
        let byte_sum: usize = plan.assignments.iter().map(|a| a.bytes).sum();
        assert_eq!(byte_sum, plan.total_bytes, "case {case}: totals must reconcile");
        let err_sum: f64 = plan.assignments.iter().map(|a| a.weighted_err).sum();
        assert_eq!(err_sum, plan.weighted_err, "case {case}: errors must reconcile");
        assert_eq!(plan.uniform_int4_bytes, uniform, "case {case}");
        assert!(plan.weighted_err.is_finite() && plan.weighted_err >= 0.0, "case {case}");
        // A zero budget can never hold the cheapest encodable bytes.
        let e = budget::solve(&specs, 0, &q).unwrap_err();
        assert_eq!(e.kind(), std::io::ErrorKind::InvalidInput, "case {case}");
    }
}

#[test]
fn prop_bigger_budget_never_downgrades_a_group() {
    const CASES: usize = 60;
    let q = GreedyQuantizer::default();
    let mut rng = Rng::new(0x0B17);
    for case in 0..CASES {
        let specs = random_specs(&mut rng);
        let uniform = budget::uniform_int4_bytes(&specs);
        let b1 = uniform + rng.below(uniform + 1);
        let b2 = b1 + 1 + rng.below(uniform + 1);
        let p1 = budget::solve(&specs, b1, &q).unwrap();
        let p2 = budget::solve(&specs, b2, &q).unwrap();
        assert!(p2.total_bytes >= p1.total_bytes, "case {case}: totals are monotone");
        for (a1, a2) in p1.assignments.iter().zip(&p2.assignments) {
            assert!(
                a2.bytes >= a1.bytes,
                "case {case} table {}: {} B at budget {b1} but {} B at bigger \
                 budget {b2} — a raise must never shrink a group",
                a1.table,
                a1.bytes,
                a2.bytes
            );
        }
    }
}

#[test]
fn prop_flat_heat_degenerates_to_uniform_int4() {
    // With no heat signal there is nothing to trade: at exactly the
    // uniform-int4 budget the solver must reproduce the paper's
    // baseline, group for group. This is where the codebook admission
    // rule earns its keep — a codebook level that beat int4 on both
    // axes would displace the baseline here. The shape class below
    // (gaussian rows ≥ 96, where the shared-codebook level is actually
    // admitted) is numerically validated: every cb→int4 upgrade ratio
    // dominates every int4→int8 ratio, so the greedy prefix spends the
    // budget exactly on restoring int4 everywhere.
    const CASES: usize = 40;
    let q = GreedyQuantizer::default();
    let mut rng = Rng::new(0xF1A7);
    for case in 0..CASES {
        let n = 2 + rng.below(4);
        let specs: Vec<GroupSpec> = (0..n)
            .map(|t| {
                let rows = [96usize, 128, 192, 256][rng.below(4)];
                let dim = [8usize, 16][rng.below(2)];
                let seed = rng.next_u64();
                GroupSpec {
                    table: t,
                    chunk: None,
                    heat: 1.0,
                    data: EmbeddingTable::randn(rows, dim, seed),
                }
            })
            .collect();
        let uniform = budget::uniform_int4_bytes(&specs);
        let plan = budget::solve(&specs, uniform, &q).unwrap();
        for a in &plan.assignments {
            assert_eq!(
                a.format, INT4,
                "case {case} table {}: flat heat at the uniform budget must \
                 degenerate to int4 everywhere",
                a.table
            );
        }
        assert_eq!(plan.total_bytes, uniform, "case {case}: the budget is spent exactly");
        assert_eq!(plan.weighted_err, plan.uniform_int4_err, "case {case}");
    }
}

/// Pick a re-quantization target covering every container family the
/// swap path can produce, codebooks included.
fn random_format(rng: &mut Rng) -> FormatTag {
    match rng.below(6) {
        0 => INT4,
        1 => FormatTag::Fused { nbits: 8, scale_bias: ScaleBiasDtype::F16 },
        2 => FormatTag::Fused { nbits: 8, scale_bias: ScaleBiasDtype::F32 },
        3 => FormatTag::F32,
        4 => FormatTag::Codebook { kind: CodebookKind::TwoTier { k: 4 } },
        _ => FormatTag::Codebook { kind: CodebookKind::Rowwise },
    }
}

#[test]
fn prop_online_requantize_serves_identically_to_offline_rebuild() {
    // The swap path and the offline path share one re-encoder
    // (`budget::build_table`), and a `chunk: None` assignment on a
    // row-wise table rebuilds each chunk from its own rows — so the
    // offline reference here is always built per chunk, which is exact
    // even for codebook targets (clustering is chunk-local).
    const CASES: usize = 24;
    let q = GreedyQuantizer::default();
    let mut rng = Rng::new(0xE27A);
    for case in 0..CASES {
        let tables = 1 + rng.below(2);
        // Rows divisible by every shard count in 2..=4 keep the carved
        // reference chunks aligned with the engine's row partition.
        let rows = [24usize, 48][rng.below(2)];
        let dim = [4usize, 8][rng.below(2)];
        let shards = 2 + rng.below(3);
        let chunk_rows = rows / shards;
        let masters: Vec<EmbeddingTable> =
            (0..tables).map(|_| EmbeddingTable::randn(rows, dim, rng.next_u64())).collect();
        // Half the cases run over a starved spill tier so the swapped
        // slices churn through serialization on their way back.
        let spill = rng.below(2) == 0;
        let engine = ShardedEngine::start(
            TableSet::new(masters.iter().map(|m| AnyTable::F32(m.clone())).collect()),
            &ShardConfig {
                num_shards: shards,
                small_table_rows: 0,
                resident_budget: spill.then_some(tables * rows * dim * 4 / 3),
                ..Default::default()
            },
        );
        // Random non-overlapping plan: per table either untouched, one
        // whole-table entry, or an independent format per chunk.
        let mut plan: Vec<GroupAssignment> = Vec::new();
        for t in 0..tables {
            match rng.below(3) {
                0 => {}
                1 => plan.push(GroupAssignment {
                    table: t,
                    chunk: None,
                    format: random_format(&mut rng),
                }),
                _ => {
                    for s in 0..shards {
                        if rng.below(2) == 0 {
                            plan.push(GroupAssignment {
                                table: t,
                                chunk: Some(s),
                                format: random_format(&mut rng),
                            });
                        }
                    }
                }
            }
        }
        engine
            .requantize_to(&plan, &q)
            .unwrap_or_else(|e| panic!("case {case}: valid plan must apply: {e}"));
        if spill {
            // Evict everything; the per-row sweep below promotes the
            // slices back through the spill files.
            engine.spill_all().unwrap();
        }
        for t in 0..tables {
            // The format each chunk must now hold, per the plan.
            let fmt_of = |s: usize| -> Option<FormatTag> {
                plan.iter()
                    .find(|a| a.table == t && (a.chunk.is_none() || a.chunk == Some(s)))
                    .map(|a| a.format)
            };
            for s in 0..shards {
                let (lo, hi) = (s * chunk_rows, (s + 1) * chunk_rows);
                let reference = fmt_of(s).map(|fmt| {
                    let carved = EmbeddingTable::from_data(
                        dim,
                        masters[t].data()[lo * dim..hi * dim].to_vec(),
                    );
                    TableSet::new(vec![budget::build_table(&AnyTable::F32(carved), fmt, &q)])
                });
                for i in lo..hi {
                    let ids: Vec<Vec<u32>> = (0..tables)
                        .map(|tt| if tt == t { vec![i as u32] } else { Vec::new() })
                        .collect();
                    let got = engine.lookup(&Request { ids });
                    let mut want = vec![0.0f32; dim];
                    match &reference {
                        Some(r) => r.pool(0, &[(i - lo) as u32], &mut want),
                        None => want.copy_from_slice(masters[t].row(i)),
                    }
                    assert_eq!(
                        &got[t * dim..(t + 1) * dim],
                        want.as_slice(),
                        "case {case} table {t} chunk {s} row {i} (spill: {spill}): \
                         online swap must serve the offline rebuild bit for bit"
                    );
                }
            }
        }
    }
}
