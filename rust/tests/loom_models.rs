//! Exhaustive model checks of the REAL concurrency product types, run
//! under `RUSTFLAGS="--cfg loom"` (the `loom-models` CI leg):
//!
//! ```text
//! RUSTFLAGS="--cfg loom" cargo test -p emberq --test loom_models --release
//! ```
//!
//! Under that cfg, [`emberq::util::sync`] swaps its std re-exports for the
//! instrumented primitives in [`emberq::verify`], so `WakeGate`,
//! `ClaimFlag`, and `TransitionSignal` — the exact types the sharded
//! engine and the tiered store run on in production — execute here under
//! every interleaving the checker can reach. The distilled protocol
//! models (which run in plain `cargo test` too) live in
//! [`emberq::verify::protocol`]; this binary re-runs them alongside the
//! real-type models so one CI job covers both layers.
//!
//! Ordinary builds compile this file to an empty test binary (the
//! `#![cfg(loom)]` below), so tier-1 `cargo test` is unaffected.

#![allow(unexpected_cfgs)]
#![cfg(loom)]

use std::sync::Arc;

use emberq::shard::{ClaimFlag, TransitionSignal, WakeGate};
use emberq::util::sync::atomic::{AtomicUsize, Ordering};
use emberq::verify::loom::thread;
use emberq::verify::sched::Builder;

// ---- the real WakeGate under the checker -------------------------------

/// A producer publishes work (counter increment) and wakes; a worker
/// parks until it sees the work. With spurious wakeups disabled, the only
/// way the worker ever unparks is the producer's wake — so this passing
/// proves the gate's lock round-trip makes lost wakeups impossible for
/// the exact type `shard::engine` parks on.
#[test]
fn real_wake_gate_never_loses_a_wake() {
    Builder::new().spurious(false).max_schedules(1_000_000).check(|| {
        let gate = Arc::new(WakeGate::new());
        let work = Arc::new(AtomicUsize::new(0));
        let (g2, w2) = (Arc::clone(&gate), Arc::clone(&work));
        let worker = thread::spawn(move || {
            assert!(
                g2.park_until(|| w2.load(Ordering::SeqCst) > 0),
                "gate was never shut, park_until must report work"
            );
            assert!(w2.load(Ordering::SeqCst) > 0);
        });
        work.store(1, Ordering::SeqCst);
        gate.wake();
        worker.join();
    });
}

/// Shutdown must unpark a worker that has no work, under every
/// interleaving and with spurious wakeups explored (the predicate loop
/// has to absorb them without returning early).
#[test]
fn real_wake_gate_shutdown_always_unparks() {
    Builder::new().max_schedules(1_000_000).check(|| {
        let gate = Arc::new(WakeGate::new());
        let g2 = Arc::clone(&gate);
        let worker = thread::spawn(move || {
            assert!(!g2.park_until(|| false), "only shutdown can unpark this worker");
            assert!(g2.is_shut());
        });
        gate.shutdown();
        worker.join();
    });
}

// ---- the real ClaimFlag + TransitionSignal under the checker -----------

/// Two racing claimants: exactly one may win, and after the winner
/// releases, a fresh claim must succeed — the CAS protocol the store's
/// promote/demote paths gate on.
#[test]
fn real_claim_flag_is_exclusive_under_all_interleavings() {
    Builder::new().max_schedules(1_000_000).check(|| {
        let claim = Arc::new(ClaimFlag::new());
        let wins = Arc::new(AtomicUsize::new(0));
        let (c2, w2) = (Arc::clone(&claim), Arc::clone(&wins));
        let racer = thread::spawn(move || {
            if c2.claim() {
                w2.fetch_add(1, Ordering::SeqCst);
            }
        });
        if claim.claim() {
            wins.fetch_add(1, Ordering::SeqCst);
        }
        racer.join();
        assert_eq!(wins.load(Ordering::SeqCst), 1, "exactly one claimant may win");
    });
}

/// The store's latecomer protocol on the real types: a claimant holds the
/// claim, does its "transition", releases, then notifies; a latecomer
/// waits for the release via `wait_until`. With spurious wakeups off,
/// this passing proves the signal's lock round-trip means the completion
/// broadcast can never land in the latecomer's check-then-park gap and
/// be lost — the store would otherwise hang exactly like PR 5's
/// `wait_demotes` would have.
#[test]
fn real_transition_signal_never_loses_completion() {
    Builder::new().spurious(false).max_schedules(1_000_000).check(|| {
        let claim = Arc::new(ClaimFlag::new());
        let sig = Arc::new(TransitionSignal::new());
        let done = Arc::new(AtomicUsize::new(0));
        assert!(claim.claim());
        let (c2, s2, d2) = (Arc::clone(&claim), Arc::clone(&sig), Arc::clone(&done));
        let latecomer = thread::spawn(move || {
            s2.wait_until(|| !c2.is_claimed());
            assert_eq!(d2.load(Ordering::SeqCst), 1, "release happens-after the transition");
        });
        // The "transition": publish the result, release the claim, then
        // broadcast — the order the store's finish_promote/finish_demote
        // are required to follow.
        done.store(1, Ordering::SeqCst);
        claim.release();
        sig.notify();
        latecomer.join();
    });
}

// ---- the distilled protocol models (same binary, one CI job) -----------

#[test]
fn protocol_wakeup_gate() {
    emberq::verify::protocol::wakeup_gate::check_wake_is_not_lost();
    emberq::verify::protocol::wakeup_gate::check_broken_wake_is_caught();
    emberq::verify::protocol::wakeup_gate::check_shutdown_unparks_and_survives_spurious_wakeups();
}

#[test]
fn protocol_store_transition() {
    emberq::verify::protocol::store_transition::check_promote_reads_spill_once();
    emberq::verify::protocol::store_transition::check_prefetch_stages_single_read();
    emberq::verify::protocol::store_transition::check_budget_settles_without_overshoot();
}

#[test]
fn protocol_placement_swap() {
    emberq::verify::protocol::placement_swap::check_swap_never_tears();
    emberq::verify::protocol::placement_swap::check_writers_serialise();
}
