//! Chaos scenarios: fault injection under live updates, end to end.
//!
//! These are the acceptance runs for the chaos harness
//! (`emberq::chaos`): each scenario drives seeded Zipf/diurnal traffic
//! and concurrent `update_table` writers against a spilling sharded
//! engine while faults fire, and panics if any invariant breaks —
//! bit-exactness vs the unsharded oracle, recovery after every heal,
//! budget at rest, monotone versions, no torn (mixed-version) reads.
//!
//! Every run is a pure function of its config seed: the canonical
//! scenario is executed twice and must produce identical reports. A
//! failure therefore reproduces by rerunning the same test — the
//! printed report is the repro recipe.

use emberq::chaos::{run_scenario, FaultKind, ScenarioConfig, ScenarioReport};
use emberq::sls::{backend, KernelBackend};

/// The canonical acceptance scenario: four fault kinds (three beyond
/// the transparent ones) interleaved with two concurrent updaters and
/// two checking readers over a half-budget spilling engine.
fn canonical() -> ScenarioConfig {
    ScenarioConfig {
        seed: 0xE0_BED, // stable, arbitrary
        tables: 3,
        rows: 512,
        dim: 8,
        shards: 4,
        ticks: 32,
        base_batch: 6,
        diurnal_period: 16,
        mean_pool: 4,
        zipf_alpha: 1.1,
        budget_frac: Some(0.5),
        spill_dir: None,
        updaters: 2,
        update_batches: 12,
        update_rows: 8,
        readers: 2,
        requant_commits: 0,
        faults: vec![
            FaultKind::WorkerPanic,
            FaultKind::CorruptSpill,
            FaultKind::WedgeIo,
            FaultKind::TruncateSpill,
        ],
        wedge_ms: 50,
        kernel_backend: None,
    }
}

fn assert_healthy(r: &ScenarioReport, cfg: &ScenarioConfig) {
    assert_eq!(
        r.final_version,
        1 + cfg.update_batches as u64 + cfg.requant_commits as u64,
        "every update batch and requant commit lands exactly once"
    );
    assert_eq!(r.committed_updates, cfg.update_batches as u64);
    assert_eq!(r.requant_commits, cfg.requant_commits as u64);
    assert_eq!(r.recoveries, cfg.faults.len(), "every fault heals and probes clean");
    assert!(r.bit_exact_final, "final per-row sweep must match the oracle");
    assert!(r.budget_ok, "resident bytes must settle at or under the budget");
    assert!(r.version_monotone, "versions never regress, stats agree at the end");
    assert!(r.main_reads_checked > 0, "the gated windows must not swallow every check");
}

#[test]
fn canonical_scenario_survives_four_interleaved_faults() {
    let cfg = canonical();
    let report = run_scenario(&cfg);
    assert_healthy(&report, &cfg);
    // The schedule really interleaved distinct fault kinds.
    let kinds: Vec<FaultKind> = report.schedule.iter().map(|&(_, _, k)| k).collect();
    assert_eq!(kinds, cfg.faults);
    assert!(report.schedule.windows(2).all(|w| w[0].1 < w[1].0), "windows are disjoint");
}

#[test]
fn canonical_scenario_is_deterministic() {
    // Same seed, same report — byte for byte. This is what makes a
    // chaos failure reproducible instead of a flake.
    let cfg = canonical();
    let a = run_scenario(&cfg);
    let b = run_scenario(&cfg);
    assert_eq!(a, b, "a scenario must be a pure function of its config");
    // A different seed still satisfies every invariant (the checks are
    // properties of the engine, not of one lucky interleaving).
    let other = ScenarioConfig { seed: 0xD15EA5E, ..cfg.clone() };
    assert_healthy(&run_scenario(&other), &other);
}

#[test]
fn canonical_scenario_holds_on_every_kernel_backend() {
    // Pin the engine to each runnable backend in turn. The oracle pools
    // through the process-default backend, so every window check inside
    // the run is already a cross-backend bit-exactness assertion; on
    // top of that, the schedule-derived reports must be identical —
    // the kernel backend must be invisible to every observable.
    let scalar_cfg =
        ScenarioConfig { kernel_backend: Some(KernelBackend::Scalar), ..canonical() };
    let scalar = run_scenario(&scalar_cfg);
    assert_healthy(&scalar, &scalar_cfg);

    let simd = backend::detected();
    if simd == KernelBackend::Scalar {
        eprintln!("note: no SIMD backend on this CPU; scalar-pinned leg covered the harness");
        return;
    }
    let simd_cfg = ScenarioConfig { kernel_backend: Some(simd), ..canonical() };
    let report = run_scenario(&simd_cfg);
    assert_healthy(&report, &simd_cfg);
    assert_eq!(scalar, report, "backend choice must not change a single observable");
}

#[test]
fn spill_dir_outage_degrades_to_resident_serving() {
    // Deleting the spill directory must not cost a single row: demotes
    // fail, slices stay resident (over budget beats serving nothing),
    // and serving plus updates continue bit-exactly until the heal.
    let cfg = ScenarioConfig {
        seed: 0x0D1_0,
        tables: 2,
        rows: 128,
        dim: 8,
        shards: 2,
        ticks: 16,
        base_batch: 4,
        diurnal_period: 8,
        budget_frac: None, // required: see FaultKind::SpillDirOutage
        updaters: 2,
        update_batches: 6,
        update_rows: 4,
        readers: 1,
        faults: vec![FaultKind::SpillDirOutage],
        ..ScenarioConfig::default()
    };
    let report = run_scenario(&cfg);
    assert_healthy(&report, &cfg);
    // Un-budgeted and un-gated: every main-loop request was checked.
    assert_eq!(report.recoveries, 1);
}

#[test]
fn requant_storm_races_updates_and_spill_churn_bit_exactly() {
    // Online re-quantization under fire: nine whole-table format flips
    // (int4 ↔ int8) commit through the engine's MVCC swap while two
    // updaters patch rows and the half-budget store churns slices to
    // disk. The storm is transparent — readers are held to bit-exact
    // single-version results *through* it — and every update batch and
    // requant commit must land exactly once in the final version.
    let cfg = ScenarioConfig {
        seed: 0x5702_4, // stable, arbitrary
        tables: 3,
        rows: 256,
        dim: 8,
        shards: 4,
        ticks: 24,
        base_batch: 5,
        diurnal_period: 12,
        budget_frac: Some(0.5),
        updaters: 2,
        update_batches: 8,
        update_rows: 6,
        readers: 2,
        requant_commits: 9,
        faults: vec![FaultKind::RequantStorm],
        ..ScenarioConfig::default()
    };
    let report = run_scenario(&cfg);
    assert_healthy(&report, &cfg);
    assert_eq!(report.recoveries, 1);
    // Transparent storm: no gated window ever opened, so every
    // main-loop read was checked against the oracle.
    assert_eq!(report.final_version, 1 + 8 + 9);
    assert_eq!(report, run_scenario(&cfg), "storm runs are pure functions of the config");
}
