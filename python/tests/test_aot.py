"""AOT path: lowering produces parseable HLO text with the right
entry-computation signature (the contract the Rust runtime relies on)."""

import json
import subprocess
import sys
from pathlib import Path

import jax
import numpy as np

from compile import aot, model


def test_to_hlo_text_smoke():
    specs = aot.mlp_arg_specs(batch=1)
    lowered = jax.jit(model.mlp_logits).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "f32[1,269]" in text  # batch 1 × feature_dim 269
    # return_tuple=True -> tuple root.
    assert "(f32[1])" in text or "tuple" in text


def test_dlrm_int4_artifact_contains_gather_and_dot():
    specs = aot.dlrm_arg_specs()
    import functools

    lowered = jax.jit(
        functools.partial(model.dlrm_int4_logits, dim=aot.DEMO_DIM)
    ).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text
    assert "u8[1024," in text  # stacked packed tables 4*256 rows
    assert "dot(" in text or "dot " in text  # the MLP matmuls survived


def test_aot_main_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out)],
        check=True,
        cwd=Path(__file__).resolve().parent.parent,
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["feature_dim"] == 269
    for name in manifest["artifacts"]:
        text = (out / name).read_text()
        assert "ENTRY" in text, name


def test_manifest_shapes_match_specs():
    specs = aot.mlp_arg_specs(batch=64)
    assert list(specs[0].shape) == [64, aot.FEATURE_DIM]
    # weights alternate (w, b) matching the params spec.
    ps = model.mlp_params_spec(aot.FEATURE_DIM, aot.HIDDEN)
    assert tuple(specs[1].shape) == ps[0][0]
    assert tuple(specs[2].shape) == ps[0][1]


def test_mlp_logits_numerics_after_roundtrip():
    # Lower, then execute the jitted original on the same inputs the Rust
    # side will use — consistency anchor for integration_runtime.rs, which
    # checks the PJRT result against rust-native MLP on the same weights.
    rng = np.random.default_rng(0)
    specs = aot.mlp_arg_specs(batch=1)
    args = [rng.normal(0, 0.05, s.shape).astype(np.float32) for s in specs]
    (logits,) = model.mlp_logits(*args)
    assert np.isfinite(np.asarray(logits)).all()
