"""L2 correctness: the DLRM graph (shapes, composition, reference parity)."""

import numpy as np
import jax.numpy as jnp

from compile import model
from compile.kernels import ref


def make_params(rng, feature_dim, hidden=(32, 16)):
    params = []
    prev = feature_dim
    for h in (*hidden, 1):
        params.append(
            (
                rng.normal(0, 0.1, (h, prev)).astype(np.float32),
                rng.normal(0, 0.1, h).astype(np.float32),
            )
        )
        prev = h
    return params


def flatten(params):
    out = []
    for w, b in params:
        out.extend([w, b])
    return out


def test_mlp_logits_matches_numpy():
    rng = np.random.default_rng(0)
    params = make_params(rng, 12)
    x = rng.normal(0, 1, (5, 12)).astype(np.float32)
    (got,) = model.mlp_logits(x, *flatten(params))
    # Numpy reference.
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w.T + b
        if i + 1 < len(params):
            h = np.maximum(h, 0)
    np.testing.assert_allclose(np.asarray(got), h[:, 0], rtol=2e-5, atol=1e-5)


def test_mlp_params_spec_shapes():
    spec = model.mlp_params_spec(269, (512, 512))
    assert spec[0] == ((512, 269), (512,))
    assert spec[1] == ((512, 512), (512,))
    assert spec[2] == ((1, 512), (1,))


def test_dlrm_int4_composes_sls_and_mlp():
    rng = np.random.default_rng(1)
    t, n, d, b, l, dd = 3, 32, 16, 4, 5, 7
    packed = rng.integers(0, 256, (t * n, d // 2), dtype=np.uint8)
    scale = rng.uniform(0.01, 0.1, t * n).astype(np.float32)
    bias = rng.uniform(-1, 0, t * n).astype(np.float32)
    idx = np.stack(
        [rng.integers(tt * n, (tt + 1) * n, (b, l)) for tt in range(t)], axis=1
    ).astype(np.int32)
    w = (rng.random((b, t, l)) > 0.3).astype(np.float32)
    dense = rng.normal(0, 1, (b, dd)).astype(np.float32)
    params = make_params(rng, t * d + dd)
    (got,) = model.dlrm_int4_logits(
        packed, scale, bias, idx, w, dense, *flatten(params), dim=d
    )
    # Reference: jnp SLS then jnp MLP.
    pooled = ref.sls_int4(
        packed, scale, bias, idx.reshape(b * t, l), w.reshape(b * t, l), d
    )
    feats = jnp.concatenate([pooled.reshape(b, t * d), dense], axis=1)
    want = ref.mlp_forward(feats, params)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_sigmoid_range():
    z = jnp.array([-50.0, -1.0, 0.0, 1.0, 50.0])
    p = np.asarray(model.sigmoid(z))
    assert ((p >= 0) & (p <= 1)).all()
    assert abs(p[2] - 0.5) < 1e-7
