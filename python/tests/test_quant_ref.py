"""Tests for the python reference quantizers (the cross-language oracle
itself must be right before it judges the Rust side)."""

import numpy as np
from hypothesis import given, settings, strategies as st

from compile import quant_ref as qr


@settings(max_examples=30, deadline=None)
@given(d=st.sampled_from([8, 16, 64, 256]), seed=st.integers(0, 2**31 - 1))
def test_greedy_never_worse_than_asym(d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 1, d).astype(np.float32)
    a0, a1 = qr.asym_clip(x)
    g0, g1 = qr.greedy_clip(x)
    assert qr.sq_error(x, g0, g1, 4) <= qr.sq_error(x, a0, a1, 4) + 1e-12


def test_quant_dequant_grid_exact():
    x = np.arange(16, dtype=np.float32)
    out = qr.quant_dequant(x, 0.0, 15.0, 4)
    np.testing.assert_allclose(out, x)


def test_greedy_clip_inside_range():
    rng = np.random.default_rng(7)
    x = rng.normal(0, 1, 64).astype(np.float32)
    g0, g1 = qr.greedy_clip(x)
    assert g0 >= float(x.min()) - 1e-9
    assert g1 <= float(x.max()) + 1e-9
    # Range shrinks at most r.
    assert (g1 - g0) >= (1 - 0.16) * (x.max() - x.min()) - 1e-6


def test_kmeans_exact_small_rows():
    rng = np.random.default_rng(8)
    x = rng.normal(0, 1, 12).astype(np.float32)
    cb = qr.kmeans_codebook(x)
    assert qr.codebook_mse(x, cb) == 0.0


def test_kmeans_beats_uniform_grid():
    rng = np.random.default_rng(9)
    x = rng.normal(0, 1, 128).astype(np.float32)
    cb = qr.kmeans_codebook(x)
    lo, hi = float(x.min()), float(x.max())
    grid = lo + (hi - lo) / 15 * np.arange(16, dtype=np.float32)
    assert qr.codebook_mse(x, cb) <= qr.codebook_mse(x, grid) + 1e-9


def test_golden_file_format(tmp_path):
    path = tmp_path / "golden.txt"
    qr.generate_golden(str(path))
    text = path.read_text().splitlines()
    cases = [l for l in text if l.startswith("case ")]
    assert len(cases) == 15  # 5 dims x 3 distributions
    assert any(l.startswith("greedy ") for l in text)
    assert any(l.startswith("kmeans_mse ") for l in text)
    # Inputs parse back to floats.
    inp = next(l for l in text if l.startswith("input "))
    vals = [float(v) for v in inp[len("input "):].split(",")]
    assert len(vals) == 8
