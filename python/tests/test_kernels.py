"""L1 correctness: Pallas kernels vs the pure-jnp oracle, with hypothesis
sweeping shapes and value regimes."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, rowwise_asym_quantize_pallas, sls_int4_pallas


def make_fused(rng, n, d):
    packed = rng.integers(0, 256, (n, (d + 1) // 2), dtype=np.uint8)
    scale = rng.uniform(1e-3, 0.2, n).astype(np.float32)
    bias = rng.uniform(-2.0, 1.0, n).astype(np.float32)
    return packed, scale, bias


# ---------------------------------------------------------------- sls_int4


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(4, 64),
    d=st.sampled_from([8, 16, 32, 64, 128]),
    b=st.integers(1, 8),
    l=st.integers(1, 12),
    seed=st.integers(0, 2**31 - 1),
)
def test_sls_int4_matches_ref(n, d, b, l, seed):
    rng = np.random.default_rng(seed)
    packed, scale, bias = make_fused(rng, n, d)
    idx = rng.integers(0, n, (b, l)).astype(np.int32)
    w = (rng.random((b, l)) > 0.25).astype(np.float32)
    got = np.asarray(sls_int4_pallas(packed, scale, bias, idx, w, d))
    want = np.asarray(ref.sls_int4(packed, scale, bias, idx, w, d))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sls_int4_zero_weights_zero_output():
    rng = np.random.default_rng(1)
    packed, scale, bias = make_fused(rng, 8, 16)
    idx = rng.integers(0, 8, (3, 4)).astype(np.int32)
    w = np.zeros((3, 4), np.float32)
    out = np.asarray(sls_int4_pallas(packed, scale, bias, idx, w, 16))
    assert (out == 0).all()


def test_sls_int4_single_lookup_is_dequant_row():
    rng = np.random.default_rng(2)
    packed, scale, bias = make_fused(rng, 8, 32)
    idx = np.array([[5]], np.int32)
    w = np.ones((1, 1), np.float32)
    out = np.asarray(sls_int4_pallas(packed, scale, bias, idx, w, 32))
    row = np.asarray(ref.dequantize_int4(packed, scale, bias, 32))[5]
    np.testing.assert_allclose(out[0], row, rtol=1e-6)


def test_sls_int4_duplicate_indices_accumulate():
    rng = np.random.default_rng(3)
    packed, scale, bias = make_fused(rng, 8, 16)
    idx = np.array([[2, 2, 2]], np.int32)
    w = np.ones((1, 3), np.float32)
    out = np.asarray(sls_int4_pallas(packed, scale, bias, idx, w, 16))
    row = np.asarray(ref.dequantize_int4(packed, scale, bias, 16))[2]
    np.testing.assert_allclose(out[0], 3 * row, rtol=1e-5)


def test_unpack_nibble_order():
    # Byte 0xBA -> low nibble A (=10) first, then B (=11).
    packed = np.array([[0xBA]], np.uint8)
    codes = np.asarray(ref.unpack_int4(packed, 2))
    assert codes.tolist() == [[10, 11]]


# ------------------------------------------------------- rowwise quantize


@settings(max_examples=25, deadline=None)
@given(
    blocks=st.integers(1, 6),
    block_rows=st.sampled_from([1, 2, 8]),
    d=st.sampled_from([8, 16, 64, 200]),
    sigma=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_quantize_matches_ref(blocks, block_rows, d, sigma, seed):
    rng = np.random.default_rng(seed)
    n = blocks * block_rows
    x = (rng.normal(0, sigma, (n, d))).astype(np.float32)
    c1, s1, b1 = (np.asarray(v) for v in rowwise_asym_quantize_pallas(x, 4, block_rows))
    c2, s2, b2 = (np.asarray(v) for v in ref.rowwise_asym_quantize(x, 4))
    assert (c1 == c2).all()
    np.testing.assert_allclose(s1, s2, rtol=1e-6)
    np.testing.assert_allclose(b1, b2, rtol=1e-6)


def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(4)
    x = rng.normal(0, 1, (16, 64)).astype(np.float32)
    codes, scale, bias = rowwise_asym_quantize_pallas(x, 4, 8)
    recon = np.asarray(ref.dequantize_codes(codes, scale, bias))
    err = np.abs(recon - x)
    assert (err <= np.asarray(scale)[:, None] / 2 + 1e-6).all()


def test_quantize_constant_rows():
    x = np.full((8, 16), 2.5, np.float32)
    codes, scale, bias = (np.asarray(v) for v in rowwise_asym_quantize_pallas(x, 4, 8))
    recon = np.asarray(ref.dequantize_codes(codes, scale, bias))
    np.testing.assert_allclose(recon, x)


def test_quantize_8bit_tighter_than_4bit():
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (8, 128)).astype(np.float32)
    e = {}
    for nbits in (4, 8):
        c, s, b = rowwise_asym_quantize_pallas(x, nbits, 8)
        recon = np.asarray(ref.dequantize_codes(c, s, b))
        e[nbits] = float(((recon - x) ** 2).sum())
    assert e[8] < e[4] / 50


def test_quantize_rejects_bad_block():
    x = np.zeros((10, 8), np.float32)
    with pytest.raises(AssertionError):
        rowwise_asym_quantize_pallas(x, 4, 8)


# ---------------------------------------------------------------- sls_int8

from compile.kernels import sls_int8_pallas


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(4, 48),
    d=st.sampled_from([8, 32, 96]),
    b=st.integers(1, 6),
    l=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_sls_int8_matches_ref(n, d, b, l, seed):
    rng = np.random.default_rng(seed)
    codes = rng.integers(0, 256, (n, d), dtype=np.uint8)
    scale = rng.uniform(1e-3, 0.05, n).astype(np.float32)
    bias = rng.uniform(-1.0, 0.5, n).astype(np.float32)
    idx = rng.integers(0, n, (b, l)).astype(np.int32)
    w = (rng.random((b, l)) > 0.25).astype(np.float32)
    got = np.asarray(sls_int8_pallas(codes, scale, bias, idx, w, d))
    want = np.asarray(ref.sls_int8(codes, scale, bias, idx, w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_sls_int8_single_row_identity():
    rng = np.random.default_rng(11)
    codes = rng.integers(0, 256, (4, 16), dtype=np.uint8)
    scale = np.full(4, 0.1, np.float32)
    bias = np.zeros(4, np.float32)
    idx = np.array([[2]], np.int32)
    w = np.ones((1, 1), np.float32)
    out = np.asarray(sls_int8_pallas(codes, scale, bias, idx, w, 16))
    np.testing.assert_allclose(out[0], codes[2].astype(np.float32) * 0.1, rtol=1e-6)
