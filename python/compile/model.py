"""L2: the DLRM compute graph in JAX, calling the L1 Pallas kernels.

Two jit-able entry points, both lowered to HLO text by ``aot.py``:

* :func:`mlp_logits` — the dense over-arch alone. The Rust coordinator
  does pooled lookups with its native SLS kernels and feeds the
  concatenated features plus its *trained weights* to this executable
  (weights are arguments, not constants, so one artifact serves any
  training run with the same shapes).
* :func:`dlrm_int4_logits` — the full quantized-inference graph: fused
  int4 SLS (the Pallas kernel) over stacked tables, feature concat, MLP.
  This is the artifact that proves L1 lowers into the same HLO the Rust
  runtime executes.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels import sls_int4_pallas
from compile.kernels import ref


def mlp_params_spec(feature_dim: int, hidden: tuple[int, ...] = (512, 512)):
    """[(w shape, b shape), ...] for the over-arch, Rust Linear layout."""
    dims = [feature_dim, *hidden, 1]
    return [((dims[i + 1], dims[i]), (dims[i + 1],)) for i in range(len(dims) - 1)]


def mlp_logits(x, *flat_params):
    """Over-arch forward. ``flat_params`` = w0, b0, w1, b1, ... logits [B]."""
    params = [(flat_params[i], flat_params[i + 1]) for i in range(0, len(flat_params), 2)]
    return (ref.mlp_forward(x, params),)


def dlrm_int4_logits(
    packed,  # [T*N, ceil(d/2)] uint8 — tables stacked row-wise
    scale,  # [T*N] f32
    bias,  # [T*N] f32
    indices,  # [B, T, L] int32, already offset by t*N
    weights,  # [B, T, L] f32 (0 = padding)
    dense,  # [B, dense_dim] f32
    *flat_params,  # MLP weights, Rust Linear layout
    dim: int,
):
    """Full quantized DLRM forward: Pallas SLS -> concat -> MLP.

    Pooling runs as one SLS call with B*T segments, then reshapes to the
    ``[B, T*d]`` feature block — identical to the Rust serving layout.
    """
    b, t, l = indices.shape
    pooled = sls_int4_pallas(
        packed,
        scale,
        bias,
        indices.reshape(b * t, l),
        weights.reshape(b * t, l),
        dim,
    )  # [B*T, d]
    feats = jnp.concatenate([pooled.reshape(b, t * dim), dense], axis=1)
    return mlp_logits(feats, *flat_params)


def sigmoid(z):
    """Click probability from a logit."""
    return 1.0 / (1.0 + jnp.exp(-z))
