"""Pure-jnp oracles for the Pallas kernels.

Everything here is the *specification*: straight-line jax.numpy with no
tiling, no nibble tricks, no scratch buffers. pytest checks the Pallas
kernels against these on swept shapes; the Rust kernels are cross-checked
against the same semantics through the golden files
(``compile/quant_ref.py`` -> ``rust/tests/golden_cross_lang.rs``).
"""

from __future__ import annotations

import jax.numpy as jnp


def unpack_int4(packed: jnp.ndarray, dim: int) -> jnp.ndarray:
    """Unpack [N, ceil(d/2)] uint8 nibbles to [N, d] uint8 codes.

    Low nibble is the even column (FBGEMM layout, matching the Rust
    ``FusedTable``).
    """
    lo = packed & 0x0F
    hi = packed >> 4
    # Interleave: out[:, 2i] = lo[:, i], out[:, 2i+1] = hi[:, i].
    inter = jnp.stack([lo, hi], axis=-1).reshape(packed.shape[0], -1)
    return inter[:, :dim]


def dequantize_int4(packed, scale, bias, dim):
    """De-quantize fused int4 rows to [N, d] float32."""
    codes = unpack_int4(packed, dim).astype(jnp.float32)
    return codes * scale[:, None] + bias[:, None]


def sls_int4(packed, scale, bias, indices, weights, dim):
    """Weighted SparseLengthsSum over fused int4 rows.

    packed  : [N, ceil(d/2)] uint8
    scale   : [N] f32
    bias    : [N] f32
    indices : [B, L] int32 (padded segments; padding gets weight 0)
    weights : [B, L] f32   (1.0 real lookup, 0.0 padding)
    returns : [B, d] f32 with out[b] = sum_l w[b,l] * dequant(T[idx[b,l]])
    """
    rows = dequantize_int4(packed, scale, bias, dim)  # [N, d]
    gathered = rows[indices]  # [B, L, d]
    return jnp.einsum("bl,bld->bd", weights, gathered)


def sls_int8(codes, scale, bias, indices, weights):
    """Weighted SparseLengthsSum over int8 rows (spec for sls_int8_pallas)."""
    rows = codes.astype(jnp.float32) * scale[:, None] + bias[:, None]
    return jnp.einsum("bl,bld->bd", weights, rows[indices])


def rowwise_asym_quantize(x, nbits: int = 4):
    """Row-wise range-based (ASYM) uniform quantization (paper Eq. 1).

    x : [N, d] f32
    returns (codes [N, d] uint8, scale [N] f32, bias [N] f32)
    """
    xmin = x.min(axis=1)
    xmax = x.max(axis=1)
    levels = (1 << nbits) - 1
    scale = (xmax - xmin) / levels
    scale = jnp.where((scale > 0) & jnp.isfinite(scale), scale, 1.0)
    q = jnp.round((x - xmin[:, None]) / scale[:, None])
    codes = jnp.clip(q, 0, levels).astype(jnp.uint8)
    return codes, scale, xmin


def dequantize_codes(codes, scale, bias):
    """Reconstruct floats from codes + per-row scale/bias."""
    return codes.astype(jnp.float32) * scale[:, None] + bias[:, None]


def mlp_forward(x, params):
    """The paper's over-arch MLP: FC->ReLU->...->FC(1), returns logits.

    params: list of (w [out, in], b [out]) pairs — the Rust ``Linear``
    layout, so trained Rust weights feed straight in.
    """
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w.T + b
        if i + 1 < len(params):
            h = jnp.maximum(h, 0.0)
    return h[:, 0]
