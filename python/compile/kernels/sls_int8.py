"""Pallas kernel: fused int8 de-quantization + SparseLengthsSum.

The 8-bit sibling of ``sls_int4`` — same HBM-gather / VMEM-accumulate
structure without the nibble unpack (one code per byte). Exists so the
serving tier can A/B INT8 vs INT4 artifacts with identical graph shapes
(paper Table 1 compares all three formats).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sls8_kernel(codes_ref, scale_ref, bias_ref, idx_ref, w_ref, out_ref, *, dim: int):
    length = idx_ref.shape[1]

    def body(l, acc):
        row_id = idx_ref[0, l]
        w = w_ref[0, l]
        row = codes_ref[pl.dslice(row_id, 1), :].astype(jnp.float32)  # [1, d]
        scale = scale_ref[pl.dslice(row_id, 1)]
        bias = bias_ref[pl.dslice(row_id, 1)]
        return acc + w * (row * scale[:, None] + bias[:, None])

    acc = jnp.zeros((1, dim), jnp.float32)
    acc = jax.lax.fori_loop(0, length, body, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("dim",))
def sls_int8_pallas(codes, scale, bias, indices, weights, dim: int):
    """Weighted SLS over int8 rows.

    codes   : [N, d] uint8
    scale   : [N] f32
    bias    : [N] f32
    indices : [B, L] int32
    weights : [B, L] f32
    returns : [B, d] f32
    """
    b, l = indices.shape
    return pl.pallas_call(
        functools.partial(_sls8_kernel, dim=dim),
        grid=(b,),
        in_specs=[
            pl.BlockSpec(codes.shape, lambda i: (0, 0)),
            pl.BlockSpec(scale.shape, lambda i: (0,)),
            pl.BlockSpec(bias.shape, lambda i: (0,)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, dim), jnp.float32),
        interpret=True,
    )(codes, scale, bias, indices, weights)
