"""L1: Pallas kernels for the paper's compute hot-spots.

All kernels lower with ``interpret=True`` so the HLO runs on the CPU PJRT
plugin (real TPU lowering emits Mosaic custom-calls the CPU client cannot
execute); the BlockSpec structure still expresses the HBM->VMEM schedule a
TPU deployment would use (DESIGN.md §Hardware-Adaptation).
"""

from compile.kernels.sls_int4 import sls_int4_pallas
from compile.kernels.sls_int8 import sls_int8_pallas
from compile.kernels.quantize import rowwise_asym_quantize_pallas

__all__ = ["sls_int4_pallas", "sls_int8_pallas", "rowwise_asym_quantize_pallas"]
