"""Pallas kernel: fused int4 de-quantization + SparseLengthsSum.

The paper's §4 hot-spot, rethought for TPU structure (DESIGN.md
§Hardware-Adaptation): the AVX512 CPU kernel becomes a Pallas kernel where

* the packed table stays in HBM (``pltpu.ANY``-like unblocked spec) and
  rows are gathered with dynamic slices — the analogue of the CPU's
  random-access row reads;
* each grid step owns one output segment: its indices/weights tile and its
  ``[1, d]`` accumulator live in VMEM (the scratchpad analogue of the CPU
  register accumulators);
* nibble unpack is shift/mask vector work on the VPU — SLS is
  bandwidth-bound, so the MXU is deliberately unused, exactly as the CPU
  kernel never touches the FMA-heavy matmul path.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness (vs ``ref.sls_int4``) is what we validate on
this host. Real-TPU efficiency is estimated in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _sls_kernel(packed_ref, scale_ref, bias_ref, idx_ref, w_ref, out_ref, *, dim: int):
    """One grid step = one output segment (batch element)."""
    length = idx_ref.shape[1]
    packed_cols = packed_ref.shape[1]

    def body(l, acc):
        row_id = idx_ref[0, l]
        w = w_ref[0, l]
        # Gather one packed row from the (unblocked) table: [1, P] uint8.
        row = packed_ref[pl.dslice(row_id, 1), :]
        lo = (row & 0x0F).astype(jnp.float32)
        hi = (row >> 4).astype(jnp.float32)
        # Interleave nibbles: codes[0, 2i] = lo[i], codes[0, 2i+1] = hi[i].
        codes = jnp.stack([lo, hi], axis=-1).reshape(1, 2 * packed_cols)[:, :dim]
        scale = scale_ref[pl.dslice(row_id, 1)]
        bias = bias_ref[pl.dslice(row_id, 1)]
        return acc + w * (codes * scale[:, None] + bias[:, None])

    acc = jnp.zeros((1, dim), jnp.float32)
    acc = jax.lax.fori_loop(0, length, body, acc)
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("dim",))
def sls_int4_pallas(packed, scale, bias, indices, weights, dim: int):
    """Fused int4-dequant SLS. Same contract as ``ref.sls_int4``.

    packed  : [N, ceil(d/2)] uint8   (fused-row codes; scale/bias split out
              into arrays because PJRT buffers are homogeneous)
    scale   : [N] f32
    bias    : [N] f32
    indices : [B, L] int32, padded; weights zero out the padding
    weights : [B, L] f32
    """
    b, l = indices.shape
    return pl.pallas_call(
        functools.partial(_sls_kernel, dim=dim),
        grid=(b,),
        in_specs=[
            # Table, scales, biases: unblocked — rows gathered dynamically.
            pl.BlockSpec(packed.shape, lambda i: (0, 0)),
            pl.BlockSpec(scale.shape, lambda i: (0,)),
            pl.BlockSpec(bias.shape, lambda i: (0,)),
            # Per-segment tiles.
            pl.BlockSpec((1, l), lambda i: (i, 0)),
            pl.BlockSpec((1, l), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, dim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, dim), jnp.float32),
        interpret=True,
    )(packed, scale, bias, indices, weights)
