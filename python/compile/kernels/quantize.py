"""Pallas kernel: row-wise asymmetric (ASYM) uniform quantization.

The build-time companion of the SLS kernel: quantizes a block of FP32
embedding rows to 4-bit codes + per-row scale/bias (paper Eq. 1). Each
grid step owns a ``[block_rows, d]`` tile in VMEM, computes the row
min/max reduction on the VPU, and writes codes + tails. On a real TPU this
is the producer that streams a trained table HBM->VMEM->HBM once;
``interpret=True`` here for CPU-PJRT execution.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quant_kernel(x_ref, codes_ref, scale_ref, bias_ref, *, nbits: int):
    x = x_ref[...]  # [R, d] f32 tile in VMEM
    xmin = x.min(axis=1)
    xmax = x.max(axis=1)
    levels = (1 << nbits) - 1
    scale = (xmax - xmin) / levels
    scale = jnp.where((scale > 0) & jnp.isfinite(scale), scale, 1.0)
    q = jnp.round((x - xmin[:, None]) / scale[:, None])
    codes_ref[...] = jnp.clip(q, 0, levels).astype(jnp.uint8)
    scale_ref[...] = scale
    bias_ref[...] = xmin


@functools.partial(jax.jit, static_argnames=("nbits", "block_rows"))
def rowwise_asym_quantize_pallas(x, nbits: int = 4, block_rows: int = 8):
    """Quantize [N, d] rows; returns (codes u8 [N, d], scale [N], bias [N]).

    ``N`` must be divisible by ``block_rows`` (callers pad; AOT shapes are
    static anyway). Matches ``ref.rowwise_asym_quantize``.
    """
    n, d = x.shape
    assert n % block_rows == 0, f"rows {n} not divisible by block {block_rows}"
    grid = (n // block_rows,)
    return pl.pallas_call(
        functools.partial(_quant_kernel, nbits=nbits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
            pl.BlockSpec((block_rows,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, d), jnp.uint8),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(x)
