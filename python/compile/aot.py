"""AOT lowering: JAX/Pallas -> HLO **text** -> ``artifacts/``.

Run once at build time (``make artifacts``); the Rust runtime
(`rust/src/runtime/`) loads the text with ``HloModuleProto::from_text_file``,
compiles it on the PJRT CPU client, and executes it on the serving path.

Text, not ``.serialize()``: jax >= 0.5 emits HloModuleProto with 64-bit
instruction ids that xla_extension 0.5.1 rejects (``proto.id() <=
INT_MAX``); the text parser reassigns ids (see /opt/xla-example/README.md).

Artifacts:

* ``mlp_b{B}.hlo.txt``      — dense over-arch; weights are arguments.
* ``dlrm_int4.hlo.txt``     — fused Pallas-SLS + MLP demo graph.
* ``manifest.json``         — every artifact's input shapes, for Rust.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Shapes must match what the Rust examples feed (examples/serve_quantized
# reads manifest.json and asserts).
MLP_BATCHES = (1, 16, 64)
NUM_TABLES = 8
DIM = 32
DENSE_DIM = 13
HIDDEN = (512, 512)
FEATURE_DIM = NUM_TABLES * DIM + DENSE_DIM

# dlrm_int4 demo graph shapes.
DEMO_TABLES = 4
DEMO_ROWS = 256  # per table
DEMO_DIM = 32
DEMO_BATCH = 16
DEMO_POOL = 8


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def mlp_arg_specs(batch: int):
    specs = [f32(batch, FEATURE_DIM)]
    for (wshape, bshape) in model.mlp_params_spec(FEATURE_DIM, HIDDEN):
        specs.append(f32(*wshape))
        specs.append(f32(*bshape))
    return specs


def dlrm_arg_specs():
    n = DEMO_TABLES * DEMO_ROWS
    specs = [
        jax.ShapeDtypeStruct((n, DEMO_DIM // 2), jnp.uint8),
        f32(n),
        f32(n),
        jax.ShapeDtypeStruct((DEMO_BATCH, DEMO_TABLES, DEMO_POOL), jnp.int32),
        f32(DEMO_BATCH, DEMO_TABLES, DEMO_POOL),
        f32(DEMO_BATCH, DENSE_DIM),
    ]
    feature_dim = DEMO_TABLES * DEMO_DIM + DENSE_DIM
    for (wshape, bshape) in model.mlp_params_spec(feature_dim, HIDDEN):
        specs.append(f32(*wshape))
        specs.append(f32(*bshape))
    return specs


def spec_json(spec):
    return {"shape": list(spec.shape), "dtype": str(spec.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "num_tables": NUM_TABLES,
        "dim": DIM,
        "dense_dim": DENSE_DIM,
        "hidden": list(HIDDEN),
        "feature_dim": FEATURE_DIM,
        "artifacts": {},
    }

    for batch in MLP_BATCHES:
        specs = mlp_arg_specs(batch)
        lowered = jax.jit(model.mlp_logits).lower(*specs)
        name = f"mlp_b{batch}.hlo.txt"
        path = os.path.join(args.out_dir, name)
        with open(path, "w") as f:
            f.write(to_hlo_text(lowered))
        manifest["artifacts"][name] = {
            "fn": "mlp_logits",
            "batch": batch,
            "inputs": [spec_json(s) for s in specs],
        }
        print(f"wrote {path}")

    specs = dlrm_arg_specs()
    lowered = jax.jit(
        functools.partial(model.dlrm_int4_logits, dim=DEMO_DIM)
    ).lower(*specs)
    path = os.path.join(args.out_dir, "dlrm_int4.hlo.txt")
    with open(path, "w") as f:
        f.write(to_hlo_text(lowered))
    manifest["artifacts"]["dlrm_int4.hlo.txt"] = {
        "fn": "dlrm_int4_logits",
        "tables": DEMO_TABLES,
        "rows_per_table": DEMO_ROWS,
        "dim": DEMO_DIM,
        "batch": DEMO_BATCH,
        "pool": DEMO_POOL,
        "inputs": [spec_json(s) for s in specs],
    }
    print(f"wrote {path}")

    mpath = os.path.join(args.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}")


if __name__ == "__main__":
    main()
